//! The LargeVis layout engine (paper §3.2): a probabilistic model over
//! the weighted KNN graph, optimized by asynchronous SGD.
//!
//! * [`objective`] — the probabilistic functions `f(x)` (Fig 4 family),
//!   their gradients, and the full objective (Eq. 5/6) for testing.
//! * [`sampler`] — alias tables for edge sampling (∝ w_ij) and negative
//!   sampling (∝ deg^0.75).
//! * [`sgd`] — the Hogwild asynchronous-SGD optimizer (the paper's
//!   engine; O(N) total work).
//! * [`multilevel`] — the coarse-to-fine driver: optimize a heavy-edge
//!   contracted hierarchy coarsest-first, prolongate, refine (reaches
//!   flat quality in a fraction of the fine-level samples).
//! * [`batched`] — an alternative optimizer that executes the AOT-
//!   compiled JAX/Pallas gradient artifact via PJRT (the three-layer
//!   integration path).

pub mod objective;
pub mod sampler;
pub mod sgd;
pub mod multilevel;
pub mod batched;
pub mod incremental;

use crate::data::matrix::Matrix;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

pub use objective::ProbFn;

/// LargeVis layout hyper-parameters (paper defaults).
#[derive(Clone, Debug)]
pub struct LargeVisConfig {
    /// Output dimensionality `s` (2 or 3).
    pub dim: usize,
    /// Edge samples per vertex; total T = this × N. (Paper: ~10K per
    /// vertex for 1M nodes; smaller data needs more per vertex.)
    pub samples_per_vertex: usize,
    /// Negative samples per positive edge, M (paper default 5).
    pub negatives: usize,
    /// Negative-edge weight γ (paper default 7).
    pub gamma: f32,
    /// Initial learning rate ρ₀ (paper default 1.0).
    pub rho0: f32,
    /// Probabilistic function f(x) (paper settles on 1/(1+x²)).
    pub prob_fn: ProbFn,
    /// Gradient clip per component (reference implementation: 5.0).
    pub grad_clip: f32,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LargeVisConfig {
    fn default() -> Self {
        LargeVisConfig {
            dim: 2,
            samples_per_vertex: 2000,
            negatives: 5,
            gamma: 7.0,
            rho0: 1.0,
            prob_fn: ProbFn::InvQuad { a: 1.0 },
            grad_clip: 5.0,
            threads: 0,
            seed: 0x1a9,
        }
    }
}

impl LargeVisConfig {
    /// Total number of edge samples for a graph of `n` vertices.
    pub fn total_samples(&self, n: usize) -> u64 {
        self.samples_per_vertex as u64 * n as u64
    }
}

/// Initialize a layout with small gaussian noise (as t-SNE/LargeVis do).
pub fn init_layout(n: usize, dim: usize, seed: u64) -> Matrix {
    let mut m = Matrix::zeros(n, dim);
    let mut rng = Rng::new(seed);
    for x in m.as_mut_slice().iter_mut() {
        *x = 1e-4 * rng.gaussian();
    }
    m
}

/// Lay out a weighted graph with the Hogwild engine (the paper's path).
pub fn layout(graph: &CsrGraph, cfg: &LargeVisConfig) -> Matrix {
    let mut y = init_layout(graph.n(), cfg.dim, cfg.seed);
    sgd::optimize(graph, &mut y, cfg);
    y
}

/// Lay out a weighted graph coarse-to-fine (the default pipeline path).
pub fn layout_multilevel(
    graph: &CsrGraph,
    cfg: &LargeVisConfig,
    ml: &multilevel::MultilevelConfig,
) -> Matrix {
    // The driver re-initializes at the coarsest level and overwrites
    // this buffer completely, so zeros suffice.
    let mut y = Matrix::zeros(graph.n(), cfg.dim);
    multilevel::optimize_multilevel(graph, &mut y, cfg, ml, |_, _, _| Ok(()))
        .expect("infallible hook");
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_layout_small_and_seeded() {
        let a = init_layout(100, 2, 1);
        let b = init_layout(100, 2, 1);
        assert_eq!(a, b);
        assert!(a.as_slice().iter().all(|&x| x.abs() < 1e-2));
        let c = init_layout(100, 2, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn total_samples_scales_with_n() {
        let cfg = LargeVisConfig { samples_per_vertex: 100, ..Default::default() };
        assert_eq!(cfg.total_samples(1000), 100_000);
    }
}
