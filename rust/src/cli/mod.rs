//! Hand-rolled CLI argument parser (no `clap` offline): subcommands,
//! `--flag`, `--key value`, `--key=value`, and positional arguments.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, positionals.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Args {
    /// First non-flag token (e.g. `pipeline`).
    pub command: String,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional arguments.
    pub positionals: Vec<String>,
}

/// Option keys that take a value (everything else after `--` is a flag).
const VALUE_KEYS: &[&str] = &[
    "dataset", "scale", "k", "trees", "explore-iters", "perplexity", "samples", "negatives",
    "gamma", "rho0", "threads", "seed", "out", "config", "dim", "prob-fn", "prob-a", "engine",
    "max-visits", "format", "sample", "input", "labels", "resume-from", "chunk-rows", "layout",
    "ml-levels", "ml-min-size", "ml-coarse-samples", "ml-jitter", "ml-rho-decay", "checkpoints",
    "addr", "embed-samples", "embed-k", "grid", "tile-max-points", "max-body-bytes",
    "insert-samples", "refine-samples", "refine-interval-ms", "keep-alive-max",
    "idle-timeout-ms", "max-inflight", "write-timeout-ms", "wal-segment-bytes",
    "wal-max-segments", "recovery-policy", "search", "beam-width", "search-seeds",
];

/// Parse a raw argument vector (without argv[0]).
pub fn parse(argv: &[String]) -> Result<Args> {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if VALUE_KEYS.contains(&stripped) {
                i += 1;
                let Some(v) = argv.get(i) else {
                    bail!("option --{stripped} expects a value");
                };
                args.options.insert(stripped.to_string(), v.clone());
            } else {
                args.flags.push(stripped.to_string());
            }
        } else if args.command.is_empty() {
            args.command = tok.clone();
        } else {
            args.positionals.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    /// Typed option lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.options.get(key) {
            None => Ok(default),
            Some(raw) => {
                raw.parse().map_err(|_| anyhow::anyhow!("--{key}: cannot parse {raw:?}"))
            }
        }
    }

    /// String option lookup.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// True if `--flag` present.
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

/// Usage text for the `largevis` binary.
pub const USAGE: &str = "\
largevis — LargeVis (WWW 2016) reproduction

USAGE:
    largevis <COMMAND> [OPTIONS]

COMMANDS:
    pipeline    run the full pipeline: dataset -> KNN -> weights -> layout -> SVG + report
    serve       HTTP query server over a finished run's checkpoints
    knn         build a KNN graph and report recall vs exact ground truth
    convert     convert a dataset between LargeVis text and .lvec binary (streamed)
    datasets    list the dataset registry (paper Table 1 analogs)
    info        print build/runtime information

COMMON OPTIONS:
    --dataset <name>      registry dataset (default 20ng-like); `largevis datasets` lists them
    --input <file>        read points from disk (LargeVis text or .lvec binary)
                          instead of generating a registry dataset
    --labels <file>       .lbl label file accompanying --input
    --scale <f>           fraction of the dataset's full size (default 0.1)
    --k <n>               neighbors per point (default 150)
    --trees <n>           RP-forest trees (default 4)
    --explore-iters <n>   neighbor-exploring iterations (default 1)
    --perplexity <f>      target perplexity (default 50)
    --samples <n>         SGD edge samples per vertex (default 2000)
    --negatives <n>       negative samples M (default 5)
    --gamma <f>           negative weight gamma (default 7)
    --engine <hogwild|xla>  layout engine (default hogwild)
    --layout <mode>       layout-stage mode: multilevel (default) or flat
    --threads <n>         worker threads (default: all cores)
    --seed <n>            RNG seed
    --out <dir>           output directory (default target/run)
    --config <file>       INI config file (CLI options override it)

MULTILEVEL LAYOUT (--layout multilevel, the default):
    --ml-levels <n>          max coarse levels (default 16)
    --ml-min-size <n>        stop coarsening at this many vertices (default 1024)
    --ml-coarse-samples <f>  per-vertex sample multiplier at coarse levels (default 1.0)
    --ml-jitter <f>          prolongation jitter stddev (default 0.01)
    --ml-rho-decay <f>       initial-learning-rate decay per refinement level (default 0.8)

CHECKPOINT / RESUME:
    --resume-from <stage> resume at a stage boundary (weights|layout), loading
                          earlier stages from <out>/checkpoints/
    --no-checkpoints      skip writing stage checkpoints
    --chunk-rows <n>      rows per chunk for the streaming dataset readers

CONVERT:
    largevis convert <src> <dst>   format chosen by <dst> extension
                                   (.txt/.tsv -> text, else binary)

SERVE (largevis serve):
    --checkpoints <dir>   checkpoint directory of a finished run
                          (or --out <dir> for <dir>/checkpoints)
    --addr <host:port>    listen address (default 127.0.0.1:7878; port 0 = ephemeral)
    --threads <n>         accept workers (default: all cores, capped at 16)
    --embed-samples <n>   localized-SGD steps per /embed point (default 500)
    --embed-k <n>         neighbors per /embed point (default: checkpointed k)
    --grid <n>            /viewport spatial-index cells per axis (default 64)
    --tile-max-points <n> max points rendered per /viewport tile (default 20000)
    --max-body-bytes <n>  request-body size cap (default 67108864; over it -> 413)
    --read-only           refuse POST /insert (and skip the WAL)
    --insert-samples <n>  localized-SGD steps per /insert point (default 500)
    --refine-samples <n>  background refinement steps per inserted point
                          per pass (default 200; 0 disables refinement)
    --refine-interval-ms <n>  refinement worker wake interval (default 250)
    --keep-alive-max <n>  requests served per connection (default 1000)
    --idle-timeout-ms <n> keep-alive idle timeout (default 5000)
    --max-inflight <n>    admitted-connection bound; beyond it requests are
                          shed with 503 + Retry-After (default 0 = 2*threads+8)
    --write-timeout-ms <n>  per-connection socket write timeout (default 10000)
    --wal-segment-bytes <n>  rotate the active WAL past this size (default 64MiB)
    --wal-max-segments <n>   compact into the checkpoints after this many
                             sealed segments (default 4)
    --recovery-policy <p>    WAL corruption handling: fail_fast (default) or
                             truncate (salvage clean prefix, quarantine rest)
    --search <mode>       nearest-neighbor query path for /knn, /embed and
                          inserts: graph (default, sub-linear beam walk with
                          automatic exact fallback) or exact (full scan)
    --beam-width <n>      graph-search candidate pool width (default 64)
    --search-seeds <n>    graph-search entry points kept per snapshot
                          (coarse-hierarchy centroids; default 32)
    Endpoints: POST /embed, POST /knn, POST /insert, POST /insert_batch,
               GET /viewport, GET /healthz, GET /readyz, GET /metrics
    Live inserts are WAL-logged to <checkpoints>/inserts.wal and replayed
    on startup, so a restarted server recovers them bit-identically;
    /readyz answers 503 until that replay finishes.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn v(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse(&v(&["pipeline", "--dataset", "mnist-like", "--scale=0.25", "--quiet"]))
            .unwrap();
        assert_eq!(a.command, "pipeline");
        assert_eq!(a.get_str("dataset"), Some("mnist-like"));
        assert_eq!(a.get_or::<f64>("scale", 1.0).unwrap(), 0.25);
        assert!(a.has_flag("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&v(&["knn", "--k"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&v(&["bench", "fig2", "fig3"])).unwrap();
        assert_eq!(a.positionals, vec!["fig2", "fig3"]);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&v(&["pipeline"])).unwrap();
        assert_eq!(a.get_or::<usize>("k", 150).unwrap(), 150);
        assert!(parse(&v(&["x", "--k", "NaNope"])).unwrap().get_or::<usize>("k", 1).is_err());
    }
}
