//! Shared workload setup for the figure/table benches: dataset →
//! KNN graph → weighted graph, with wall-clock accounting.

use crate::data::datasets::{self, Dataset};
use crate::graph::weights::{weighted_graph, WeightConfig};
use crate::graph::CsrGraph;
use crate::knn::explore::{largevis_knn, LargeVisKnnConfig};
use crate::knn::KnnGraph;

/// A fully prepared layout workload.
pub struct Workload {
    /// The generated dataset.
    pub dataset: Dataset,
    /// Its approximate KNN graph.
    pub knn: KnnGraph,
    /// The perplexity-weighted symmetrized graph.
    pub graph: CsrGraph,
    /// Seconds spent building the KNN graph.
    pub knn_secs: f64,
}

/// Build the standard workload the paper uses for the layout benches:
/// LargeVis KNN (default forest + 1 exploring pass), perplexity 50.
pub fn prepare(dataset: &str, scale: f64, k: usize, seed: u64) -> Workload {
    let ds = datasets::generate(dataset, scale, seed)
        .unwrap_or_else(|| panic!("unknown dataset {dataset}"));
    let k = k.min(ds.points.n().saturating_sub(1)).max(2);
    let t0 = std::time::Instant::now();
    let knn = largevis_knn(&ds.points, k, &LargeVisKnnConfig::default());
    let knn_secs = t0.elapsed().as_secs_f64();
    let graph = weighted_graph(&knn, &WeightConfig::default());
    Workload { dataset: ds, knn, graph, knn_secs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_small_workload() {
        let w = prepare("20ng-like", 0.01, 8, 1);
        assert!(w.graph.n() > 0);
        assert!(w.graph.n_directed_edges() > 0);
        assert!(w.knn_secs >= 0.0);
        assert_eq!(w.knn.n(), w.dataset.points.n());
    }
}
