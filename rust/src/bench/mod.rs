//! Bench harness (no `criterion` offline): timing loops with warmup,
//! aligned table printing matching the paper's rows, and TSV output so
//! figures can be re-plotted.

pub mod workloads;

use crate::util::stats::{summarize, Summary};
use std::io::Write;
use std::time::Instant;

/// Time `f` for `iters` measured runs after `warmup` runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// A results table with aligned columns, printable and TSV-dumpable.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column names.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Print to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Write as TSV into `target/figures/<name>.tsv`.
    pub fn write_tsv(&self, name: &str) -> anyhow::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/figures");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.tsv"));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "# {}", self.title)?;
        writeln!(f, "{}", self.header.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        f.flush()?;
        Ok(path)
    }
}

/// Scale knob for bench workloads: `LARGEVIS_BENCH_SCALE` (default 1.0)
/// multiplies dataset sizes so CI can run tiny and a workstation full.
pub fn bench_scale() -> f64 {
    std::env::var("LARGEVIS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_returns_iters_samples() {
        let s = time_fn(1, 5, || 2 + 2);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["alg", "secs"]);
        t.row(&["largevis".into(), "1.5".into()]);
        t.row(&["tsne".into(), "9.9".into()]);
        let p = t.write_tsv("test_demo").unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("largevis\t1.5"));
        t.print();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
