//! Exact KNN by brute force — O(N²d), parallel over query chunks. Used
//! as ground truth for recall curves (Figs 2–3) and as the exact path
//! on small inputs. The blocked inner loop is also the shape the
//! `pdist` XLA artifact accelerates (see `vis::batched`).
//!
//! This scan is the one place where the bounded early-exit kernel beats
//! the batched gather kernel: the heap fills within the first K rows
//! and from then on most of the N candidates exceed the threshold
//! within the first 32-lane blocks, so [`kernels::sqdist_bounded`]
//! (SIMD inside each block, exit between blocks) skips the bulk of the
//! d=784 lanes that a full batched evaluation would compute.

use crate::data::matrix::Matrix;
use crate::kernels;
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::pool;

/// Exact K-nearest-neighbor graph over all points.
pub fn exact_knn(data: &Matrix, k: usize, threads: usize) -> KnnGraph {
    let ids: Vec<usize> = (0..data.n()).collect();
    let rows = exact_knn_for(data, &ids, k, threads);
    KnnGraph { neighbors: rows, k }
}

/// Exact K nearest neighbors for the given query ids only.
///
/// Kept distances are always exact (the early exit only short-circuits
/// candidates that are already over the heap threshold), so the result
/// matches a full per-pair scan of the same kernel variant.
pub fn exact_knn_for(
    data: &Matrix,
    queries: &[usize],
    k: usize,
    threads: usize,
) -> Vec<Vec<(u32, f32)>> {
    let threads = if threads == 0 { pool::default_threads() } else { threads };
    let n = data.n();
    pool::parallel_map_with(
        queries.len(),
        threads,
        |_worker| BoundedMaxHeap::new(k),
        |heap, qi| {
            let q = queries[qi];
            let qrow = data.row(q);
            heap.reset(k);
            for j in 0..n {
                if j == q {
                    continue;
                }
                let bound = heap.threshold();
                let d = kernels::sqdist_bounded(qrow, data.row(j), bound);
                if d < bound {
                    heap.push(j as u32, d, false);
                }
            }
            heap.drain_sorted_pairs()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;

    #[test]
    fn matches_naive_on_small_input() {
        let (m, _) = gaussian_mixture(60, 8, 3, 0.2, 1);
        let g = exact_knn(&m, 5, 2);
        g.check_invariants().unwrap();
        // Naive check for a few query points.
        for q in [0usize, 17, 59] {
            let mut dists: Vec<(u32, f32)> = (0..60)
                .filter(|&j| j != q)
                .map(|j| (j as u32, m.sqdist(q, j)))
                .collect();
            dists.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            let expect: Vec<u32> = dists.iter().take(5).map(|&(id, _)| id).collect();
            let got: Vec<u32> = g.neighbors[q].iter().map(|&(id, _)| id).collect();
            assert_eq!(got, expect, "query {q}");
        }
    }

    #[test]
    fn k_larger_than_n() {
        let (m, _) = gaussian_mixture(5, 4, 2, 0.0, 2);
        let g = exact_knn(&m, 10, 1);
        assert!(g.neighbors.iter().all(|nb| nb.len() == 4));
        g.check_invariants().unwrap();
    }

    #[test]
    fn thread_count_invariant() {
        let (m, _) = gaussian_mixture(80, 6, 4, 0.1, 3);
        let a = exact_knn(&m, 4, 1);
        let b = exact_knn(&m, 4, 7);
        assert_eq!(a.neighbors, b.neighbors);
    }
}
