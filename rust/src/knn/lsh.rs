//! Locality-sensitive hashing for Euclidean distance (Datar et al. 2004,
//! p-stable scheme) — the hashing family of KNN baselines the paper's
//! related work covers.
//!
//! `L` hash tables, each keyed by `m` concatenated p-stable projections
//! `h(x) = floor((a·x + b) / w)`. Candidates are points sharing a
//! bucket in any table; recall grows with `L` at linear memory cost.

use crate::data::matrix::Matrix;
use crate::kernels::{self, dot, sqdist};
use crate::knn::{KnnGraph, ScanScratch};
use crate::util::pool;
use crate::util::rng::Rng;

/// LSH parameters.
#[derive(Clone, Debug)]
pub struct LshConfig {
    /// Number of hash tables L (recall knob).
    pub n_tables: usize,
    /// Projections concatenated per table key.
    pub hashes_per_table: usize,
    /// Bucket width w (relative to the data's scale; see `auto_width`).
    pub width: f32,
    /// Derive `width` from a sample of pairwise distances when > 0.
    pub auto_width_sample: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LshConfig {
    fn default() -> Self {
        LshConfig {
            n_tables: 8,
            hashes_per_table: 8,
            width: 1.0,
            auto_width_sample: 256,
            threads: 0,
            seed: 0x15a,
        }
    }
}

struct HashTable {
    /// Projection vectors, `m × d` flattened.
    projections: Vec<f32>,
    /// Offsets b per projection.
    offsets: Vec<f32>,
    /// Bucket map: key -> point ids.
    buckets: std::collections::HashMap<u64, Vec<u32>>,
    m: usize,
    d: usize,
    width: f32,
}

impl HashTable {
    fn key(&self, row: &[f32]) -> u64 {
        // FNV-style mix of the m bucket indices.
        let mut h = 0xcbf29ce484222325u64;
        for j in 0..self.m {
            let proj = &self.projections[j * self.d..(j + 1) * self.d];
            let v = ((dot(row, proj) + self.offsets[j]) / self.width).floor() as i64;
            h ^= v as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Build a KNN graph with p-stable LSH.
pub fn lsh_knn(data: &Matrix, k: usize, cfg: &LshConfig) -> KnnGraph {
    let n = data.n();
    let d = data.d();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let mut rng = Rng::new(cfg.seed);

    // Auto-tune the bucket width to the median sampled pair distance so
    // the scheme works across datasets of different scales.
    let width = if cfg.auto_width_sample > 0 && n >= 2 {
        let mut dists: Vec<f64> = Vec::with_capacity(cfg.auto_width_sample);
        for _ in 0..cfg.auto_width_sample {
            let a = rng.below(n);
            let b = rng.below(n);
            if a != b {
                dists.push((sqdist(data.row(a), data.row(b)) as f64).sqrt());
            }
        }
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = dists.get(dists.len() / 2).copied().unwrap_or(1.0) as f32;
        (cfg.width * med / 2.0).max(1e-6)
    } else {
        cfg.width
    };

    // Build tables.
    let mut tables: Vec<HashTable> = (0..cfg.n_tables)
        .map(|_| {
            let m = cfg.hashes_per_table;
            let projections: Vec<f32> =
                (0..m * d).map(|_| rng.gaussian() / (d as f32).sqrt()).collect();
            let offsets: Vec<f32> = (0..m).map(|_| rng.range_f32(0.0, width)).collect();
            HashTable {
                projections,
                offsets,
                buckets: std::collections::HashMap::new(),
                m,
                d,
                width,
            }
        })
        .collect();
    for table in tables.iter_mut() {
        for i in 0..n {
            let key = table.key(data.row(i));
            table.buckets.entry(key).or_default().push(i as u32);
        }
    }

    // Query: union of buckets across tables, deduped (the query's own
    // row and cross-table repeats are skipped *before* paying for a
    // distance), then one batched SIMD pass over the distinct set.
    let neighbors = pool::parallel_map_with(
        n,
        threads,
        |_worker| ScanScratch::new(n, k),
        |s, i| {
            let q = data.row(i);
            s.begin(k, i as u32);
            for table in &tables {
                if let Some(bucket) = table.buckets.get(&table.key(q)) {
                    for &cand in bucket {
                        if s.seen.insert(cand) {
                            s.cand.push(cand);
                        }
                    }
                }
            }
            kernels::sqdist_batch(q, data, &s.cand, &mut s.dist);
            for (&cand, &d) in s.cand.iter().zip(s.dist.iter()) {
                if d < s.heap.threshold() {
                    s.heap.push(cand, d, false);
                }
            }
            s.heap.drain_sorted_pairs()
        },
    );
    KnnGraph { neighbors, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn recall_grows_with_tables() {
        let (m, _) = gaussian_mixture(600, 16, 4, 0.2, 1);
        let truth = exact_knn(&m, 8, 2);
        let r1 = lsh_knn(&m, 8, &LshConfig { n_tables: 1, ..Default::default() })
            .recall_against(&truth);
        let r16 = lsh_knn(&m, 8, &LshConfig { n_tables: 16, ..Default::default() })
            .recall_against(&truth);
        assert!(r16 > r1, "tables 16 {r16} <= 1 {r1}");
        assert!(r16 > 0.3, "16-table recall too low: {r16}");
    }

    #[test]
    fn buckets_group_similar_points() {
        // Two far-apart tight blobs: same-blob pairs should share
        // buckets far more often than cross-blob pairs.
        let (m, labels) = gaussian_mixture(300, 8, 2, 0.0, 2);
        let g = lsh_knn(&m, 5, &LshConfig::default());
        let mut same = 0usize;
        let mut total = 0usize;
        for i in 0..300 {
            for &(j, _) in &g.neighbors[i] {
                total += 1;
                if labels[i] == labels[j as usize] {
                    same += 1;
                }
            }
        }
        assert!(total > 0);
        assert!(same as f64 / total as f64 > 0.9, "{same}/{total}");
    }

    #[test]
    fn invariants_hold() {
        let (m, _) = gaussian_mixture(200, 12, 3, 0.3, 3);
        let g = lsh_knn(&m, 6, &LshConfig::default());
        g.check_invariants().unwrap();
    }
}
