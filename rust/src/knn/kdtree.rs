//! k-d trees (Bentley 1975; Friedman–Bentley–Finkel 1977) — the
//! classical space-partitioning baseline the paper's related work
//! discusses: excellent at low dimensionality, degrading sharply as d
//! grows (the curse that motivates RP trees).
//!
//! Median split on the axis of greatest spread; exact backtracking
//! search with an optional `max_visits` budget for an anytime
//! approximate mode (same knob as our vp-tree baseline).

use crate::data::matrix::Matrix;
use crate::kernels;
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::pool;

/// k-d tree search configuration.
#[derive(Clone, Debug)]
pub struct KdTreeConfig {
    /// Max tree nodes visited per query (`usize::MAX` = exact).
    pub max_visits: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Max points per leaf bucket.
    pub leaf_size: usize,
}

impl Default for KdTreeConfig {
    fn default() -> Self {
        KdTreeConfig { max_visits: usize::MAX, threads: 0, leaf_size: 16 }
    }
}

enum Node {
    Split { axis: u32, value: f32, left: u32, right: u32 },
    Leaf { start: u32, len: u32 },
}

/// A bucketed k-d tree over the dataset.
pub struct KdTree {
    nodes: Vec<Node>,
    points: Vec<u32>,
}

impl KdTree {
    /// Build over all points.
    pub fn build(data: &Matrix, leaf_size: usize) -> Self {
        let mut idx: Vec<u32> = (0..data.n() as u32).collect();
        let mut t = KdTree { nodes: Vec::with_capacity(2 * data.n() / leaf_size.max(1)), points: Vec::new() };
        t.build_rec(data, &mut idx, leaf_size.max(2));
        t
    }

    fn build_rec(&mut self, data: &Matrix, idx: &mut [u32], leaf_size: usize) -> u32 {
        let node_id = self.nodes.len() as u32;
        if idx.len() <= leaf_size {
            let start = self.points.len() as u32;
            self.points.extend_from_slice(idx);
            self.nodes.push(Node::Leaf { start, len: idx.len() as u32 });
            return node_id;
        }
        // Axis of greatest spread (sampled for speed on big nodes).
        let d = data.d();
        let sample: Vec<u32> = idx.iter().step_by((idx.len() / 64).max(1)).copied().collect();
        let mut best_axis = 0usize;
        let mut best_spread = -1f32;
        for axis in 0..d {
            let (mut lo, mut hi) = (f32::MAX, f32::MIN);
            for &p in &sample {
                let v = data.row(p as usize)[axis];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if hi - lo > best_spread {
                best_spread = hi - lo;
                best_axis = axis;
            }
        }
        if best_spread <= 0.0 {
            // All sampled points identical on every axis: make a leaf.
            let start = self.points.len() as u32;
            self.points.extend_from_slice(idx);
            self.nodes.push(Node::Leaf { start, len: idx.len() as u32 });
            return node_id;
        }
        // Median split on that axis.
        let mid = idx.len() / 2;
        idx.select_nth_unstable_by(mid, |&a, &b| {
            data.row(a as usize)[best_axis]
                .partial_cmp(&data.row(b as usize)[best_axis])
                .unwrap()
        });
        let value = data.row(idx[mid] as usize)[best_axis];
        self.nodes.push(Node::Split { axis: best_axis as u32, value, left: 0, right: 0 });
        let (l_idx, r_idx) = idx.split_at_mut(mid);
        let left = self.build_rec(data, l_idx, leaf_size);
        let right = self.build_rec(data, r_idx, leaf_size);
        match &mut self.nodes[node_id as usize] {
            Node::Split { left: l, right: r, .. } => {
                *l = left;
                *r = right;
            }
            _ => unreachable!(),
        }
        node_id
    }

    /// K nearest neighbors of `q` (excluding `self_id`), visiting at
    /// most `max_visits` nodes.
    pub fn knn(
        &self,
        data: &Matrix,
        q: &[f32],
        self_id: Option<u32>,
        k: usize,
        max_visits: usize,
    ) -> Vec<(u32, f32)> {
        let mut heap = BoundedMaxHeap::new(k);
        let mut dist = Vec::new();
        self.knn_with(data, q, self_id, k, max_visits, &mut heap, &mut dist)
    }

    /// [`KdTree::knn`] with caller-provided scratch (heap + distance
    /// buffer), for allocation-free per-worker reuse.
    #[allow(clippy::too_many_arguments)]
    pub fn knn_with(
        &self,
        data: &Matrix,
        q: &[f32],
        self_id: Option<u32>,
        k: usize,
        max_visits: usize,
        heap: &mut BoundedMaxHeap,
        dist: &mut Vec<f32>,
    ) -> Vec<(u32, f32)> {
        heap.reset(k);
        let mut visits = 0usize;
        self.search(data, q, self_id, 0, heap, dist, &mut visits, max_visits);
        heap.drain_sorted_pairs()
    }

    #[allow(clippy::too_many_arguments)]
    fn search(
        &self,
        data: &Matrix,
        q: &[f32],
        self_id: Option<u32>,
        node: u32,
        heap: &mut BoundedMaxHeap,
        dist: &mut Vec<f32>,
        visits: &mut usize,
        max_visits: usize,
    ) {
        if *visits >= max_visits {
            return;
        }
        *visits += 1;
        match &self.nodes[node as usize] {
            Node::Leaf { start, len } => {
                // Whole-bucket batched SIMD scan; the query's own row
                // (present in exactly one leaf) is skipped by id.
                let pts = &self.points[*start as usize..(*start + *len) as usize];
                kernels::sqdist_batch(q, data, pts, dist);
                for (&p, &d) in pts.iter().zip(dist.iter()) {
                    if Some(p) == self_id {
                        continue;
                    }
                    if d < heap.threshold() {
                        heap.push(p, d, false);
                    }
                }
            }
            Node::Split { axis, value, left, right } => {
                let diff = q[*axis as usize] - value;
                let (near, far) = if diff < 0.0 { (*left, *right) } else { (*right, *left) };
                self.search(data, q, self_id, near, heap, dist, visits, max_visits);
                // Prune the far side iff the splitting plane is farther
                // than the current worst kept distance.
                if diff * diff < heap.threshold() {
                    self.search(data, q, self_id, far, heap, dist, visits, max_visits);
                }
            }
        }
    }
}

/// Build a KNN graph by querying a k-d tree for every point.
pub fn kd_tree_knn(data: &Matrix, k: usize, cfg: &KdTreeConfig) -> KnnGraph {
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let tree = KdTree::build(data, cfg.leaf_size);
    let neighbors = pool::parallel_map_with(
        data.n(),
        threads,
        |_worker| (BoundedMaxHeap::new(k), Vec::<f32>::new()),
        |(heap, dist), i| {
            tree.knn_with(data, data.row(i), Some(i as u32), k, cfg.max_visits, heap, dist)
        },
    );
    KnnGraph { neighbors, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn exact_search_matches_bruteforce_low_dim() {
        let (m, _) = gaussian_mixture(400, 4, 3, 0.2, 1);
        let truth = exact_knn(&m, 8, 2);
        let g = kd_tree_knn(&m, 8, &KdTreeConfig::default());
        let recall = g.recall_against(&truth);
        assert!(recall > 0.999, "kd exact recall {recall}");
        g.check_invariants().unwrap();
    }

    #[test]
    fn high_dim_needs_more_visits_than_low_dim() {
        // The curse of dimensionality: with the same visit budget, low-d
        // recall beats high-d recall — the paper's related-work claim.
        let budget = 60;
        let (lo, _) = gaussian_mixture(800, 4, 4, 0.2, 2);
        let (hi, _) = gaussian_mixture(800, 64, 4, 0.2, 2);
        let t_lo = exact_knn(&lo, 8, 2);
        let t_hi = exact_knn(&hi, 8, 2);
        let r_lo = kd_tree_knn(&lo, 8, &KdTreeConfig { max_visits: budget, ..Default::default() })
            .recall_against(&t_lo);
        let r_hi = kd_tree_knn(&hi, 8, &KdTreeConfig { max_visits: budget, ..Default::default() })
            .recall_against(&t_hi);
        assert!(r_lo > r_hi + 0.15, "lo-d {r_lo} vs hi-d {r_hi}");
    }

    #[test]
    fn duplicate_points_handled() {
        let m = Matrix::from_vec(vec![2.0; 40 * 3], 40, 3);
        let g = kd_tree_knn(&m, 4, &KdTreeConfig::default());
        g.check_invariants().unwrap();
        assert!(g.neighbors.iter().all(|nb| nb.len() == 4));
    }

    use crate::data::matrix::Matrix;
}
