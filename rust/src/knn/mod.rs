//! K-nearest-neighbor graph construction (paper §3.1).
//!
//! The paper's contribution is [`rptree`] (random projection forest for
//! a rough graph) + [`explore`] (neighbor-of-neighbor refinement to
//! ~100% recall). Baselines for Fig 2: [`vptree`] (what t-SNE uses),
//! [`nndescent`], and plain RP-forests without exploring. [`bruteforce`]
//! provides exact ground truth for recall evaluation.

pub mod bruteforce;
pub mod rptree;
pub mod vptree;
pub mod kdtree;
pub mod lsh;
pub mod nndescent;
pub mod explore;
pub mod search;

use crate::data::matrix::Matrix;
use crate::util::heap::BoundedMaxHeap;
use crate::util::visited::VisitedSet;

/// Shared per-worker scratch for the batched KNN scan loops (neighbor
/// exploring, RP-forest queries, LSH buckets): a visited set for
/// candidate dedup, the K-best heap, and the candidate-id / distance
/// buffers fed to [`crate::kernels::sqdist_batch`]. Built once per
/// worker via `pool::parallel_map_with` and reused for every node, so
/// the hot loops perform no per-node heap allocation.
pub(crate) struct ScanScratch {
    /// Epoch-stamped dedup set over point ids `0..n`.
    pub seen: VisitedSet,
    /// Bounded K-best heap, reset per query.
    pub heap: BoundedMaxHeap,
    /// Distinct candidate ids for the batched kernel.
    pub cand: Vec<u32>,
    /// Batched squared distances, aligned with `cand`.
    pub dist: Vec<f32>,
}

impl ScanScratch {
    /// Scratch for a dataset of `n` points and `k` neighbors.
    pub fn new(n: usize, k: usize) -> Self {
        ScanScratch {
            seen: VisitedSet::new(n),
            heap: BoundedMaxHeap::new(k),
            cand: Vec::new(),
            dist: Vec::new(),
        }
    }

    /// Start a new query: empty heap of capacity `k`, fresh visited
    /// generation with the query itself marked, cleared candidates.
    pub fn begin(&mut self, k: usize, query_id: u32) {
        self.heap.reset(k);
        self.seen.clear();
        self.seen.insert(query_id);
        self.cand.clear();
    }
}

/// Read-only neighbor-list access shared by the flat [`KnnGraph`] and
/// the serving path's chunked copy-on-write store
/// ([`ChunkedKnn`](crate::data::chunked::ChunkedKnn)); the navigable
/// graph walk and the incremental edge calibration read through this so
/// they serve both representations.
pub trait NeighborStore {
    /// Number of points.
    fn n(&self) -> usize;
    /// Requested K.
    fn k(&self) -> usize;
    /// Neighbor list of point `i`: sorted `(id, sqdist)` pairs.
    fn row(&self, i: usize) -> &[(u32, f32)];
}

impl NeighborStore for KnnGraph {
    fn n(&self) -> usize {
        self.neighbors.len()
    }
    fn k(&self) -> usize {
        self.k
    }
    fn row(&self, i: usize) -> &[(u32, f32)] {
        &self.neighbors[i]
    }
}

/// A (possibly approximate) K-nearest-neighbor graph: for each point,
/// up to K neighbors sorted ascending by squared distance.
#[derive(Clone, Debug)]
pub struct KnnGraph {
    /// `neighbors[i]` = sorted `(id, sqdist)` pairs, self excluded.
    pub neighbors: Vec<Vec<(u32, f32)>>,
    /// Requested K.
    pub k: usize,
}

impl KnnGraph {
    /// Empty graph over `n` points.
    pub fn empty(n: usize, k: usize) -> Self {
        KnnGraph { neighbors: vec![Vec::new(); n], k }
    }

    /// Number of points.
    pub fn n(&self) -> usize {
        self.neighbors.len()
    }

    /// Mean recall against an exact graph (fraction of true neighbors
    /// recovered, averaged over points) — the paper's Fig 2/3 "accuracy".
    pub fn recall_against(&self, truth: &KnnGraph) -> f64 {
        assert_eq!(self.n(), truth.n());
        let mut hit = 0usize;
        let mut total = 0usize;
        for (mine, real) in self.neighbors.iter().zip(&truth.neighbors) {
            let truth_set: std::collections::HashSet<u32> =
                real.iter().map(|&(id, _)| id).collect();
            total += truth_set.len();
            hit += mine.iter().filter(|&&(id, _)| truth_set.contains(&id)).count();
        }
        if total == 0 {
            1.0
        } else {
            hit as f64 / total as f64
        }
    }

    /// Validate structural invariants (no self-loops, sorted, distinct,
    /// ≤ K entries). Used by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, nb) in self.neighbors.iter().enumerate() {
            if nb.len() > self.k {
                return Err(format!("node {i}: {} neighbors > k={}", nb.len(), self.k));
            }
            let mut seen = std::collections::HashSet::new();
            let mut last = f32::NEG_INFINITY;
            for &(id, d) in nb {
                if id as usize == i {
                    return Err(format!("node {i}: self-loop"));
                }
                if !seen.insert(id) {
                    return Err(format!("node {i}: duplicate neighbor {id}"));
                }
                if d < last {
                    return Err(format!("node {i}: distances not sorted"));
                }
                if !d.is_finite() {
                    return Err(format!("node {i}: non-finite distance"));
                }
                last = d;
            }
        }
        Ok(())
    }
}

/// Exact recall of `approx` over a random sample of `sample` nodes
/// (recomputing ground truth only for the sampled nodes — cheap enough
/// for the big benches).
pub fn sampled_recall(
    data: &Matrix,
    approx: &KnnGraph,
    sample: usize,
    seed: u64,
    threads: usize,
) -> f64 {
    let mut rng = crate::util::rng::Rng::new(seed);
    let ids = rng.sample_indices(data.n(), sample.min(data.n()));
    let truth = bruteforce::exact_knn_for(data, &ids, approx.k, threads);
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &i) in truth.iter().zip(&ids) {
        let ts: std::collections::HashSet<u32> = row.iter().map(|&(id, _)| id).collect();
        total += ts.len();
        hit += approx.neighbors[i].iter().filter(|&&(id, _)| ts.contains(&id)).count();
    }
    if total == 0 {
        1.0
    } else {
        hit as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invariants_catch_problems() {
        let mut g = KnnGraph::empty(3, 2);
        g.neighbors[0] = vec![(1, 0.5), (2, 1.0)];
        assert!(g.check_invariants().is_ok());
        g.neighbors[1] = vec![(1, 0.1)];
        assert!(g.check_invariants().unwrap_err().contains("self-loop"));
        g.neighbors[1] = vec![(0, 1.0), (0, 2.0)];
        assert!(g.check_invariants().unwrap_err().contains("duplicate"));
        g.neighbors[1] = vec![(0, 2.0), (2, 1.0)];
        assert!(g.check_invariants().unwrap_err().contains("sorted"));
    }

    #[test]
    fn recall_perfect_and_zero() {
        let mut a = KnnGraph::empty(2, 2);
        a.neighbors[0] = vec![(1, 1.0)];
        a.neighbors[1] = vec![(0, 1.0)];
        assert_eq!(a.recall_against(&a), 1.0);
        let empty = KnnGraph::empty(2, 2);
        assert_eq!(empty.recall_against(&a), 0.0);
    }
}
