//! Neighbor exploring (paper §3.1, Algorithm 1 step 3) — the paper's
//! key idea for KNN construction: start from a *cheap, rough* RP-forest
//! graph and refine it with "a neighbor of my neighbor is also likely
//! to be my neighbor". One or two iterations push recall to ~100% at a
//! fraction of the cost of building more trees (Figs 2–3).

use crate::data::matrix::Matrix;
use crate::kernels;
use crate::knn::rptree::{rp_forest_knn, RpForestConfig};
use crate::knn::{KnnGraph, ScanScratch};
use crate::util::pool;

/// LargeVis KNN configuration: a small forest + exploring iterations.
#[derive(Clone, Debug)]
pub struct LargeVisKnnConfig {
    /// RP-forest used for initialization (few trees!).
    pub forest: RpForestConfig,
    /// Neighbor-exploring iterations (paper: 1 usually suffices).
    pub iters: usize,
    /// Candidate cap per node per iteration (bounds the O(K²) join).
    pub max_candidates: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
}

impl Default for LargeVisKnnConfig {
    fn default() -> Self {
        LargeVisKnnConfig {
            forest: RpForestConfig { n_trees: 4, ..Default::default() },
            iters: 1,
            max_candidates: usize::MAX,
            threads: 0,
        }
    }
}

/// One neighbor-exploring pass: for every node i, evaluate neighbors of
/// its current neighbors and keep the best K. Returns the refined graph.
///
/// Dedup matters: in dense regions the same candidate appears in many
/// neighbor lists, and distance evaluations dominate at high d (§Perf).
/// Distinct candidates are collected first, then evaluated in one
/// batched SIMD pass ([`kernels::sqdist_batch`]). The per-worker
/// [`ScanScratch`] (visited set, heap, buffers) is reused across every
/// node a worker processes, so the hot loop performs **zero per-node
/// heap allocation** — the only allocation left is the returned
/// neighbor list itself, which the output graph owns.
pub fn explore_once(data: &Matrix, graph: &KnnGraph, cfg: &LargeVisKnnConfig) -> KnnGraph {
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let k = graph.k;
    let n = data.n();
    let neighbors = pool::parallel_map_with(
        n,
        threads,
        |_worker| ScanScratch::new(n, k),
        |s, i| {
            let q = data.row(i);
            s.begin(k, i as u32);
            // Seed with current neighbors so quality never regresses.
            for &(j, d) in &graph.neighbors[i] {
                s.heap.push(j, d, false);
                s.seen.insert(j);
            }
            // Collect the distinct neighbor-of-neighbor candidates.
            collect_candidates(graph, i, cfg.max_candidates, s);
            // One batched SIMD evaluation of the whole candidate set.
            kernels::sqdist_batch(q, data, &s.cand, &mut s.dist);
            for (&l, &d) in s.cand.iter().zip(s.dist.iter()) {
                if d < s.heap.threshold() {
                    s.heap.push(l, d, false);
                }
            }
            s.heap.drain_sorted_pairs()
        },
    );
    KnnGraph { neighbors, k }
}

/// Collect node `i`'s distinct neighbor-of-neighbor candidates into
/// `s.cand`, at most `max_candidates` of them (`s.seen` must hold the
/// current generation with `i` and its direct neighbors already
/// marked — [`ScanScratch::begin`] plus the heap-seeding loop).
///
/// The budget check runs *before* a candidate is marked visited: the
/// previous order inserted the candidate that exhausted the budget
/// into `seen` and then broke out, so N+1 candidates were marked
/// visited while only N were ever scored — the exhausting candidate
/// was silently dropped for the whole query (off-by-one). Now the
/// visited set and the scored set stay in lockstep: exactly
/// `min(max_candidates, available)` candidates are marked and scored.
pub(crate) fn collect_candidates(
    graph: &KnnGraph,
    i: usize,
    max_candidates: usize,
    s: &mut ScanScratch,
) {
    let mut budget = max_candidates;
    'outer: for &(j, _) in &graph.neighbors[i] {
        for &(l, _) in &graph.neighbors[j as usize] {
            if s.seen.contains(l) {
                continue;
            }
            if budget == 0 {
                break 'outer;
            }
            s.seen.insert(l);
            budget -= 1;
            s.cand.push(l);
        }
    }
}

/// The full LargeVis KNN pipeline: small RP-forest, then `iters`
/// exploring passes (Algorithm 1).
pub fn largevis_knn(data: &Matrix, k: usize, cfg: &LargeVisKnnConfig) -> KnnGraph {
    let mut forest_cfg = cfg.forest.clone();
    if forest_cfg.threads == 0 {
        forest_cfg.threads = cfg.threads;
    }
    let mut g = rp_forest_knn(data, k, &forest_cfg);
    for _ in 0..cfg.iters {
        g = explore_once(data, &g, cfg);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;
    use crate::knn::rptree::RpForestConfig;

    #[test]
    fn exploring_improves_recall() {
        let (m, _) = gaussian_mixture(800, 24, 5, 0.3, 1);
        let truth = exact_knn(&m, 10, 4);
        let cfg = LargeVisKnnConfig {
            forest: RpForestConfig { n_trees: 2, leaf_size: 16, threads: 2, seed: 2, ..Default::default() },
            iters: 0,
            max_candidates: usize::MAX,
            threads: 2,
        };
        let rough = largevis_knn(&m, 10, &cfg);
        let r0 = rough.recall_against(&truth);
        let refined = explore_once(&m, &rough, &cfg);
        let r1 = refined.recall_against(&truth);
        let refined2 = explore_once(&m, &refined, &cfg);
        let r2 = refined2.recall_against(&truth);
        let refined3 = explore_once(&m, &refined2, &cfg);
        let r3 = refined3.recall_against(&truth);
        assert!(r1 > r0, "one pass should improve: {r0} -> {r1}");
        assert!(r2 >= r1 - 1e-9, "second pass must not regress: {r1} -> {r2}");
        // K=10 explores only K² candidates per pass (the paper uses
        // K=150, where one pass suffices); three passes must get close.
        assert!(r3 > 0.93, "three passes should be near-perfect: {r0} -> {r1} -> {r2} -> {r3}");
    }

    #[test]
    fn exploring_never_loses_found_neighbors() {
        let (m, _) = gaussian_mixture(300, 16, 3, 0.2, 3);
        let cfg = LargeVisKnnConfig::default();
        let g0 = rp_forest_knn(&m, 8, &cfg.forest);
        let g1 = explore_once(&m, &g0, &cfg);
        // Mean distance must be monotone non-increasing per node.
        for i in 0..m.n() {
            let mean0: f32 =
                g0.neighbors[i].iter().map(|&(_, d)| d).sum::<f32>() / g0.neighbors[i].len().max(1) as f32;
            let mean1: f32 =
                g1.neighbors[i].iter().map(|&(_, d)| d).sum::<f32>() / g1.neighbors[i].len().max(1) as f32;
            assert!(mean1 <= mean0 + 1e-5, "node {i} regressed: {mean0} -> {mean1}");
        }
    }

    #[test]
    fn full_pipeline_invariants() {
        let (m, _) = gaussian_mixture(400, 12, 4, 0.2, 5);
        let g = largevis_knn(&m, 15, &LargeVisKnnConfig::default());
        g.check_invariants().unwrap();
        assert!(g.neighbors.iter().all(|nb| nb.len() == 15));
    }

    #[test]
    fn budget_exhaustion_marks_exactly_what_it_scores() {
        use crate::knn::ScanScratch;
        // Node 0's neighbors are 1 and 2; their lists fan out to 8
        // distinct second-hop candidates (3..=10), in a known order.
        let k = 5;
        let mut g = KnnGraph::empty(11, k);
        g.neighbors[0] = vec![(1, 0.1), (2, 0.2)];
        g.neighbors[1] = vec![(3, 0.1), (4, 0.2), (5, 0.3), (6, 0.4), (0, 0.5)];
        g.neighbors[2] = vec![(4, 0.1), (7, 0.2), (8, 0.3), (9, 0.4), (10, 0.5)];
        let run = |budget: usize| -> (Vec<u32>, ScanScratch) {
            let mut s = ScanScratch::new(11, k);
            s.begin(k, 0);
            for &(j, _) in &g.neighbors[0] {
                s.seen.insert(j);
            }
            collect_candidates(&g, 0, budget, &mut s);
            (s.cand.clone(), s)
        };
        // Unlimited: all 8 distinct candidates, duplicates (4) deduped.
        let (all, _) = run(usize::MAX);
        assert_eq!(all, vec![3, 4, 5, 6, 7, 8, 9, 10]);
        // Budgeted: exactly `max_candidates` evaluated — and the
        // candidate that would exhaust the budget (7, the next distinct
        // one) is NOT left marked visited-but-unscored, which is the
        // off-by-one this test pins down.
        for budget in 1..=7 {
            let (cand, s) = run(budget);
            assert_eq!(cand.len(), budget, "budget {budget}");
            assert_eq!(cand, all[..budget], "budget {budget}");
            let first_unscored = all[budget];
            assert!(
                !s.seen.contains(first_unscored),
                "budget {budget}: candidate {first_unscored} marked visited but never scored"
            );
        }
    }

    #[test]
    fn candidate_budget_respected() {
        let (m, _) = gaussian_mixture(200, 8, 2, 0.2, 7);
        let cfg = LargeVisKnnConfig { max_candidates: 5, ..Default::default() };
        let g0 = rp_forest_knn(&m, 10, &cfg.forest);
        // Should run (fast) and keep invariants even with a tiny budget.
        let g1 = explore_once(&m, &g0, &cfg);
        g1.check_invariants().unwrap();
    }
}
