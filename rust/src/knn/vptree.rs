//! Vantage-point trees (Yianilos, 1993) — the KNN method used by
//! Barnes–Hut t-SNE, and the paper's main Fig 2 baseline.
//!
//! Exact search prunes subtrees by the triangle inequality; in high
//! dimensions the pruning bound is rarely tight so search degenerates
//! toward a linear scan — exactly the deterioration the paper reports.
//! A `max_visits` budget turns the exact search into an anytime
//! approximate one, tracing Fig 2's time-vs-recall curve.

use crate::data::matrix::Matrix;
use crate::kernels;
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::pool;
use crate::util::rng::Rng;

/// VP-tree search configuration.
#[derive(Clone, Debug)]
pub struct VpTreeConfig {
    /// Max nodes visited per query (`usize::MAX` = exact search).
    pub max_visits: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed (vantage points are sampled randomly).
    pub seed: u64,
}

impl Default for VpTreeConfig {
    fn default() -> Self {
        VpTreeConfig { max_visits: usize::MAX, threads: 0, seed: 0x59 }
    }
}

struct VpNode {
    /// Point id of the vantage point.
    vantage: u32,
    /// Median distance (not squared) separating inside from outside.
    radius: f32,
    /// Child node indices (u32::MAX = none).
    inside: u32,
    outside: u32,
}

/// A vantage-point tree over the dataset.
pub struct VpTree {
    nodes: Vec<VpNode>,
    root: u32,
}

const NONE: u32 = u32::MAX;

impl VpTree {
    /// Build over all points.
    pub fn build(data: &Matrix, seed: u64) -> Self {
        let mut items: Vec<u32> = (0..data.n() as u32).collect();
        let mut t = VpTree { nodes: Vec::with_capacity(data.n()), root: NONE };
        let mut rng = Rng::new(seed);
        let root = t.build_rec(data, &mut items, &mut rng);
        t.root = root;
        t
    }

    fn build_rec(&mut self, data: &Matrix, items: &mut [u32], rng: &mut Rng) -> u32 {
        if items.is_empty() {
            return NONE;
        }
        let node_id = self.nodes.len() as u32;
        // Random vantage point (swap to front).
        let v = rng.below(items.len());
        items.swap(0, v);
        let vantage = items[0];
        let rest = &mut items[1..];
        if rest.is_empty() {
            self.nodes.push(VpNode { vantage, radius: 0.0, inside: NONE, outside: NONE });
            return node_id;
        }
        // Median split by distance to the vantage point.
        let vrow = data.row(vantage as usize).to_vec();
        let mut dists: Vec<(f32, u32)> = rest
            .iter()
            .map(|&p| (kernels::sqdist(&vrow, data.row(p as usize)).sqrt(), p))
            .collect();
        let mid = dists.len() / 2;
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let radius = dists[mid.min(dists.len() - 1)].0;
        for (slot, &(_, p)) in rest.iter_mut().zip(&dists) {
            *slot = p;
        }
        self.nodes.push(VpNode { vantage, radius, inside: NONE, outside: NONE });
        let (ins, outs) = rest.split_at_mut(mid);
        let inside = self.build_rec(data, ins, rng);
        let outside = self.build_rec(data, outs, rng);
        let node = &mut self.nodes[node_id as usize];
        node.inside = inside;
        node.outside = outside;
        node_id
    }

    /// K nearest neighbors of `q` (id `self_id` excluded), visiting at
    /// most `max_visits` tree nodes.
    pub fn knn(
        &self,
        data: &Matrix,
        q: &[f32],
        self_id: Option<u32>,
        k: usize,
        max_visits: usize,
    ) -> Vec<(u32, f32)> {
        let mut heap = BoundedMaxHeap::new(k);
        let mut visits = 0usize;
        self.search(data, q, self_id, self.root, &mut heap, &mut visits, max_visits);
        heap.into_sorted().iter().map(|c| (c.id, c.dist)).collect()
    }

    fn search(
        &self,
        data: &Matrix,
        q: &[f32],
        self_id: Option<u32>,
        node: u32,
        heap: &mut BoundedMaxHeap,
        visits: &mut usize,
        max_visits: usize,
    ) {
        if node == NONE || *visits >= max_visits {
            return;
        }
        *visits += 1;
        let n = &self.nodes[node as usize];
        let d2 = kernels::sqdist(q, data.row(n.vantage as usize));
        if Some(n.vantage) != self_id && d2 < heap.threshold() {
            heap.push(n.vantage, d2, false);
        }
        let d = d2.sqrt();
        // Tau = current worst kept distance (in unsquared space).
        let tau = heap.threshold().sqrt();
        if d < n.radius {
            self.search(data, q, self_id, n.inside, heap, visits, max_visits);
            if d + tau >= n.radius {
                self.search(data, q, self_id, n.outside, heap, visits, max_visits);
            }
        } else {
            self.search(data, q, self_id, n.outside, heap, visits, max_visits);
            if d - tau <= n.radius {
                self.search(data, q, self_id, n.inside, heap, visits, max_visits);
            }
        }
    }
}

/// Build a KNN graph by querying a VP-tree for every point.
pub fn vp_tree_knn(data: &Matrix, k: usize, cfg: &VpTreeConfig) -> KnnGraph {
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let tree = VpTree::build(data, cfg.seed);
    let neighbors = pool::parallel_map(data.n(), threads, |i| {
        tree.knn(data, data.row(i), Some(i as u32), k, cfg.max_visits)
    });
    KnnGraph { neighbors, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn exact_search_matches_bruteforce() {
        let (m, _) = gaussian_mixture(300, 6, 3, 0.2, 1);
        let truth = exact_knn(&m, 8, 2);
        let g = vp_tree_knn(&m, 8, &VpTreeConfig::default());
        let recall = g.recall_against(&truth);
        assert!(recall > 0.999, "exact VP search recall {recall}");
    }

    #[test]
    fn budget_trades_recall() {
        let (m, _) = gaussian_mixture(800, 32, 4, 0.2, 2);
        let truth = exact_knn(&m, 10, 4);
        let tight = vp_tree_knn(&m, 10, &VpTreeConfig { max_visits: 12, ..Default::default() })
            .recall_against(&truth);
        let loose = vp_tree_knn(&m, 10, &VpTreeConfig { max_visits: 2000, ..Default::default() })
            .recall_against(&truth);
        assert!(loose > tight, "loose {loose} <= tight {tight}");
    }

    #[test]
    fn graph_invariants() {
        let (m, _) = gaussian_mixture(150, 10, 3, 0.3, 3);
        let g = vp_tree_knn(&m, 6, &VpTreeConfig::default());
        g.check_invariants().unwrap();
        assert!(g.neighbors.iter().all(|nb| nb.len() == 6));
    }
}
