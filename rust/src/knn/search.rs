//! Graph-navigating approximate nearest-neighbor *query* search.
//!
//! The offline builders in this module's siblings ([`crate::knn::explore`],
//! [`crate::knn::nndescent`]) exploit the paper's §3 observation that a
//! neighbor of a neighbor is likely a neighbor. The same observation
//! makes the finished KNN graph a navigable search structure at query
//! time: a greedy best-first walk that repeatedly expands the closest
//! unexpanded candidate converges on the query's true neighborhood
//! after touching a tiny, roughly N-independent fraction of the points
//! — this is how the live server answers `/knn`, `/embed`, and insert
//! base-neighbor lookups in sub-linear time instead of the O(N·d)
//! exact scan.
//!
//! Three pieces:
//!
//! - [`SearchIndex`]: small per-snapshot metadata built once at
//!   load/publish — entry-point seeds (coarse-level centroids from
//!   [`crate::graph::coarsen::build_hierarchy`], falling back to
//!   grid-cell representatives and then a deterministic stride) plus
//!   the per-level coarsening maps.
//! - [`search_nearest`]: the beam search itself — an epoch-stamped
//!   [`VisitedSet`] for dedup, a [`BoundedMaxHeap`] result pool of
//!   width `beam`, and distances through the batched
//!   [`crate::kernels::sqdist_batch`] kernel.
//! - [`QueryStats`]: per-query visited/scored counters and the
//!   fallback flag, surfaced as `serve.search_*` metrics and asserted
//!   sub-linear by the recall harness.
//!
//! Every behavior here is testable against ground truth because the
//! exact scan ([`crate::kernels::nearest_k`]) stays available as a
//! bit-true oracle: when the walk exhausts its scoring budget or
//! cannot reach `k` candidates (disconnected component, empty graph),
//! it *falls back to that oracle* rather than returning a silently
//! truncated result.

use crate::data::matrix::RowStore;
use crate::graph::coarsen::{build_hierarchy, CoarsenConfig};
use crate::graph::CsrGraph;
use crate::kernels;
use crate::knn::NeighborStore;
use crate::render::grid::GridIndex;
use crate::util::heap::BoundedMaxHeap;
use crate::util::visited::VisitedSet;
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// How the entry-point seeds of a [`SearchIndex`] were obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedSource {
    /// Coarse-level centroids out of the HEM coarsening hierarchy.
    Centroid,
    /// Grid-cell representatives from the layout's spatial index
    /// (hierarchy unavailable, e.g. an edgeless graph).
    Grid,
    /// Deterministic stride over point ids (no hierarchy, no grid).
    Random,
}

/// Per-snapshot search metadata: entry seeds and coarsening maps.
///
/// Built once at checkpoint load / epoch publish and shared read-only
/// (behind an `Arc`) by every server worker. Small by construction:
/// `seeds` is capped at the configured seed count and `maps` holds one
/// `u32` per point per level (~2·N total across the whole hierarchy).
#[derive(Clone, Debug)]
pub struct SearchIndex {
    /// Entry-point ids the beam search starts from, sorted ascending.
    seeds: Vec<u32>,
    /// Per-level fine→coarse vertex maps, finest first — `maps[0]`
    /// maps original points to level-1 clusters. Retained so future
    /// multi-level descent (and diagnostics) need not re-coarsen.
    maps: Vec<Vec<u32>>,
    /// Provenance of `seeds`.
    source: SeedSource,
}

impl SearchIndex {
    /// Build search metadata for `knn` over `data`.
    ///
    /// The preferred path contracts the KNN graph with heavy-edge
    /// matching down to ~`n_seeds` coarse clusters and picks, per
    /// cluster, the member nearest the cluster's data-space mean — a
    /// centroid-like, well-spread entry set (the landmark idea of
    /// ShapeVis). When no hierarchy can be built (edgeless graph) the
    /// seeds come from `grid` cell representatives, and failing that
    /// from a deterministic id stride. Always yields at least one seed
    /// for a non-empty dataset.
    ///
    /// Generic over [`RowStore`]/[`NeighborStore`] so both the offline
    /// flat matrices and the serving path's chunked stores build the
    /// same index.
    pub fn build(
        data: &impl RowStore,
        knn: &impl NeighborStore,
        grid: Option<&GridIndex>,
        n_seeds: usize,
    ) -> Self {
        let n = knn.n();
        let n_seeds = n_seeds.max(1);
        assert_eq!(n, data.n(), "search index: knn graph and data disagree on n");
        if n == 0 {
            return SearchIndex { seeds: Vec::new(), maps: Vec::new(), source: SeedSource::Random };
        }
        if n <= n_seeds {
            // Seeding every point makes the first beam round an exact
            // scan of the whole (tiny) dataset — trivially correct.
            return SearchIndex {
                seeds: (0..n as u32).collect(),
                maps: Vec::new(),
                source: SeedSource::Centroid,
            };
        }

        // Undirected, deduplicated edge list from the (directed) KNN
        // lists. `CsrGraph::from_undirected` does not merge duplicate
        // pairs, so (i→j, j→i) mutual neighbors must collapse to one
        // edge here. Weight 1/(1+d²) so HEM matches close pairs first.
        let mut pairs: Vec<(u32, u32, f64)> = Vec::new();
        for i in 0..n {
            let nb = knn.row(i);
            let i = i as u32;
            for &(j, d) in nb {
                if i != j {
                    pairs.push((i.min(j), i.max(j), 1.0 / (1.0 + d as f64)));
                }
            }
        }
        pairs.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        pairs.dedup_by_key(|p| (p.0, p.1));

        if !pairs.is_empty() {
            let g = CsrGraph::from_undirected(n, &pairs);
            let cfg = CoarsenConfig { min_coarse_size: n_seeds, ..CoarsenConfig::default() };
            let hierarchy = build_hierarchy(&g, &cfg);
            if let Some(coarsest) = hierarchy.last() {
                let maps: Vec<Vec<u32>> = hierarchy.iter().map(|c| c.map.clone()).collect();
                let seeds = centroid_seeds(data, &maps, coarsest.graph.n());
                if !seeds.is_empty() {
                    return SearchIndex {
                        seeds: cap_seeds(seeds, n_seeds),
                        maps,
                        source: SeedSource::Centroid,
                    };
                }
            }
        }

        if let Some(grid) = grid {
            let mut seeds = grid.cell_representatives(n_seeds);
            seeds.retain(|&id| (id as usize) < n);
            if !seeds.is_empty() {
                seeds.sort_unstable();
                return SearchIndex { seeds, maps: Vec::new(), source: SeedSource::Grid };
            }
        }

        // Deterministic stride: spread over the id range without any
        // auxiliary structure.
        let stride = n.div_ceil(n_seeds).max(1);
        let seeds: Vec<u32> = (0..n as u32).step_by(stride).collect();
        SearchIndex { seeds, maps: Vec::new(), source: SeedSource::Random }
    }

    /// Entry-point ids (ascending, distinct).
    pub fn seeds(&self) -> &[u32] {
        &self.seeds
    }

    /// Per-level fine→coarse maps, finest first.
    pub fn maps(&self) -> &[Vec<u32>] {
        &self.maps
    }

    /// Number of coarsening levels behind the seeds (0 for fallbacks).
    pub fn levels(&self) -> usize {
        self.maps.len()
    }

    /// How the seeds were obtained.
    pub fn source(&self) -> SeedSource {
        self.source
    }
}

/// Per-cluster member closest to the cluster's data-space mean, for
/// the coarsest level of `maps` (which has `coarse_n` clusters).
fn centroid_seeds(data: &impl RowStore, maps: &[Vec<u32>], coarse_n: usize) -> Vec<u32> {
    let n = data.n();
    let d = data.d();
    // Compose the per-level maps into point → coarsest-cluster.
    let mut cluster = vec![0u32; n];
    for (i, c) in cluster.iter_mut().enumerate() {
        let mut v = i as u32;
        for m in maps {
            v = m[v as usize];
        }
        *c = v;
    }
    // Mean of each cluster in data space.
    let mut sums = vec![0f64; coarse_n * d];
    let mut counts = vec![0u64; coarse_n];
    for (i, &c) in cluster.iter().enumerate() {
        let row = data.row(i);
        let s = &mut sums[c as usize * d..(c as usize + 1) * d];
        for (acc, &x) in s.iter_mut().zip(row) {
            *acc += x as f64;
        }
        counts[c as usize] += 1;
    }
    // Member nearest the mean; ties to the lowest id because points
    // are visited in ascending order with a strict `<`.
    let mut best: Vec<(f64, u32)> = vec![(f64::INFINITY, u32::MAX); coarse_n];
    for (i, &c) in cluster.iter().enumerate() {
        let cnt = counts[c as usize];
        if cnt == 0 {
            continue;
        }
        let mean = &sums[c as usize * d..(c as usize + 1) * d];
        let mut dist = 0f64;
        for (&m, &x) in mean.iter().zip(data.row(i)) {
            let diff = m / cnt as f64 - x as f64;
            dist += diff * diff;
        }
        if dist < best[c as usize].0 {
            best[c as usize] = (dist, i as u32);
        }
    }
    let mut seeds: Vec<u32> = best.iter().filter(|&&(_, id)| id != u32::MAX).map(|&(_, id)| id).collect();
    seeds.sort_unstable();
    seeds.dedup();
    seeds
}

/// Stride `seeds` down to at most `cap` entries (keeps the spread).
fn cap_seeds(seeds: Vec<u32>, cap: usize) -> Vec<u32> {
    if seeds.len() <= cap {
        return seeds;
    }
    let stride = seeds.len().div_ceil(cap);
    seeds.into_iter().step_by(stride).collect()
}

/// Counters for one [`search_nearest`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Distinct points entered into the visited set (seeds included).
    pub visited: u64,
    /// Distance evaluations performed by the graph walk (excludes the
    /// exact-fallback scan, which is accounted by `fallback`).
    pub scored: u64,
    /// True when the result came from the exact oracle instead of the
    /// graph walk (budget exhausted, unreachable `k`, or no seeds).
    pub fallback: bool,
}

/// [`QueryStats`] accumulated over many queries — one insert batch,
/// one `/embed` request, one metrics flush.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchTotals {
    /// Queries folded in.
    pub queries: u64,
    /// Sum of per-query `visited`.
    pub visited: u64,
    /// Sum of per-query `scored`.
    pub scored: u64,
    /// Queries that fell back to the exact scan.
    pub fallbacks: u64,
}

impl SearchTotals {
    /// Fold one query's counters in.
    pub fn absorb(&mut self, s: &QueryStats) {
        self.queries += 1;
        self.visited += s.visited;
        self.scored += s.scored;
        if s.fallback {
            self.fallbacks += 1;
        }
    }

    /// Fold another accumulator in (batch-of-batches aggregation).
    pub fn merge(&mut self, o: &SearchTotals) {
        self.queries += o.queries;
        self.visited += o.visited;
        self.scored += o.scored;
        self.fallbacks += o.fallbacks;
    }
}

/// A shared [`SearchIndex`] plus the beam width to query it with — the
/// handle the incremental-insert path holds so its base-neighbor
/// lookups go through the graph walk instead of the exact scan.
#[derive(Clone, Debug)]
pub struct SearchHandle {
    /// Snapshot-shared index (cheap to clone).
    pub index: std::sync::Arc<SearchIndex>,
    /// Beam width passed to [`search_nearest`].
    pub beam_width: usize,
}

// Per-thread reusable buffers for the walk, sized lazily to the
// largest n seen by this thread (same idiom as the GATHER scratch in
// `kernels::batch`). Keeps the per-query hot path allocation-free
// beyond the returned result vector.
struct SearchScratch {
    seen: VisitedSet,
    pool: BoundedMaxHeap,
    exact_heap: BoundedMaxHeap,
    frontier: BinaryHeap<Reverse<(u32, u32)>>,
    cand: Vec<u32>,
    dist: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Option<SearchScratch>> = const { RefCell::new(None) };
}

/// The scoring budget after which the walk abandons the graph and
/// falls back to the exact scan: generous enough that a healthy walk
/// (≈ beam × degree scored) never hits it, and `≥ n` once the beam
/// covers the dataset so the beam-≥-N degeneration property holds.
fn score_budget(n: usize, ef: usize) -> u64 {
    ((n / 10).max(ef * 16).max(256)) as u64
}

/// Greedy best-first beam search for the `k` nearest rows of `data`
/// to `query`, walking `knn`'s adjacency from `index`'s seeds.
///
/// Returns `(id, sqdist)` pairs sorted ascending by `(dist, id)` —
/// the same order as the exact [`crate::kernels::nearest_k`] oracle —
/// plus the per-query [`QueryStats`]. The result pool is
/// `max(beam_width, k)` wide; the walk stops when the closest
/// unexpanded candidate is no better than the pool's worst kept
/// distance. On budget exhaustion or when fewer than `min(k, n)`
/// points were reachable (disconnected component), the exact scan
/// answers instead and `stats.fallback` is set — never a silently
/// short result.
pub fn search_nearest(
    query: &[f32],
    data: &impl RowStore,
    knn: &impl NeighborStore,
    index: &SearchIndex,
    k: usize,
    beam_width: usize,
) -> (Vec<(u32, f32)>, QueryStats) {
    let n = data.n();
    let mut stats = QueryStats::default();
    if n == 0 {
        return (Vec::new(), stats);
    }
    debug_assert_eq!(knn.n(), n, "search: knn graph and data disagree on n");
    let k = k.max(1);
    let ef = beam_width.max(k);
    let budget = score_budget(n, ef);
    let want = k.min(n);

    SCRATCH.with(|cell| {
        let mut slot = cell.borrow_mut();
        let scratch = slot.get_or_insert_with(|| SearchScratch {
            seen: VisitedSet::new(n),
            pool: BoundedMaxHeap::new(ef),
            exact_heap: BoundedMaxHeap::new(1),
            frontier: BinaryHeap::new(),
            cand: Vec::new(),
            dist: Vec::new(),
        });
        if scratch.seen.capacity() < n {
            scratch.seen = VisitedSet::new(n);
        }
        scratch.seen.clear();
        scratch.pool.reset(ef);
        scratch.frontier.clear();

        // Round 0: score every seed in one batch.
        scratch.cand.clear();
        for &s in index.seeds() {
            if (s as usize) < n && scratch.seen.insert(s) {
                scratch.cand.push(s);
            }
        }
        let mut fell_back = false;
        if scratch.cand.is_empty() {
            fell_back = true; // no usable seeds: straight to the oracle
        } else {
            stats.visited += scratch.cand.len() as u64;
            stats.scored += scratch.cand.len() as u64;
            let SearchScratch { cand, dist, pool, frontier, .. } = &mut *scratch;
            kernels::sqdist_batch(query, data, cand, dist);
            for (&id, &d) in cand.iter().zip(dist.iter()) {
                pool.push(id, d, false);
                frontier.push(Reverse((d.to_bits(), id)));
            }

            // Greedy expansion: always the closest unexpanded point;
            // `(dist_bits, id)` keys make tie order deterministic
            // (squared distances are non-negative, so the IEEE bit
            // pattern is order-preserving).
            while let Some(Reverse((dbits, u))) = scratch.frontier.pop() {
                if scratch.pool.len() >= ef && f32::from_bits(dbits) > scratch.pool.threshold() {
                    break; // nothing in the frontier can improve the pool
                }
                scratch.cand.clear();
                for &(v, _) in knn.row(u as usize) {
                    if (v as usize) < n && scratch.seen.insert(v) {
                        scratch.cand.push(v);
                    }
                }
                if scratch.cand.is_empty() {
                    continue;
                }
                stats.visited += scratch.cand.len() as u64;
                stats.scored += scratch.cand.len() as u64;
                let SearchScratch { cand, dist, pool, frontier, .. } = &mut *scratch;
                kernels::sqdist_batch(query, data, cand, dist);
                for (&id, &d) in cand.iter().zip(dist.iter()) {
                    if d <= pool.threshold() {
                        pool.push(id, d, false);
                        frontier.push(Reverse((d.to_bits(), id)));
                    }
                }
                if stats.scored > budget {
                    fell_back = true;
                    break;
                }
            }
        }

        let mut out = if fell_back {
            Vec::new()
        } else {
            let mut all = scratch.pool.drain_sorted_pairs();
            all.truncate(k);
            all
        };
        if !fell_back && out.len() < want {
            fell_back = true; // disconnected / under-reached: use the oracle
        }
        if fell_back {
            stats.fallback = true;
            out = kernels::nearest_k(query, data, k, &mut scratch.dist, &mut scratch.exact_heap);
        }
        (out, stats)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::knn::{bruteforce, KnnGraph};
    use crate::util::rng::Rng;

    fn gaussian_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec((0..n * d).map(|_| rng.gaussian()).collect(), n, d)
    }

    fn exact_graph(data: &Matrix, k: usize) -> KnnGraph {
        bruteforce::exact_knn(data, k, 2)
    }

    #[test]
    fn finds_high_recall_neighbors_on_gaussian_data() {
        let data = gaussian_matrix(600, 8, 42);
        let knn = exact_graph(&data, 10);
        let idx = SearchIndex::build(&data, &knn, None, 16);
        assert_eq!(idx.source(), SeedSource::Centroid);
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(1);
        let (mut hits, mut total, mut fallbacks) = (0usize, 0usize, 0usize);
        for q in 0..100 {
            let row: Vec<f32> = data.row(q * 6 % 600).to_vec();
            let (got, stats) = search_nearest(&row, &data, &knn, &idx, 10, 32);
            let truth = kernels::nearest_k(&row, &data, 10, &mut dists, &mut heap);
            let ts: std::collections::HashSet<u32> = truth.iter().map(|&(id, _)| id).collect();
            hits += got.iter().filter(|&&(id, _)| ts.contains(&id)).count();
            total += ts.len();
            fallbacks += stats.fallback as usize;
            assert!(stats.visited > 0 && stats.scored > 0);
        }
        // The release-mode harness (tests/search_recall.rs) holds the
        // 0.95 line at scale; this debug-mode smoke allows a little
        // slack on its tiny dataset.
        let recall = hits as f64 / total as f64;
        assert!(recall >= 0.90, "recall {recall} too low ({fallbacks} fallbacks)");
    }

    /// Scalar integer squared distance — exact in f32 for small ints.
    fn int_sqdist(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    /// Make every stored edge bidirectional and add a ring backbone,
    /// so the *directed* traversal of [`search_nearest`] can reach the
    /// whole graph from any seed.
    fn symmetrize_with_ring(data: &Matrix, g: &mut KnnGraph) {
        let n = g.n();
        let mut extra: Vec<(usize, (u32, f32))> = Vec::new();
        for (i, nb) in g.neighbors.iter().enumerate() {
            for &(j, d) in nb {
                extra.push((j as usize, (i as u32, d)));
            }
        }
        for i in 0..n {
            let j = (i + 1) % n;
            if i == j {
                continue;
            }
            let d = int_sqdist(data.row(i), data.row(j));
            extra.push((i, (j as u32, d)));
            extra.push((j, (i as u32, d)));
        }
        for (i, e) in extra {
            if !g.neighbors[i].iter().any(|&(id, _)| id == e.0) {
                g.neighbors[i].push(e);
            }
        }
        for nb in &mut g.neighbors {
            nb.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        }
    }

    #[test]
    fn wide_beam_matches_exact_oracle() {
        // Connected graph + beam ≥ N ⇒ the pool never evicts, the walk
        // floods the whole graph, result == exact oracle. Small
        // integer coordinates keep every squared distance exactly
        // representable, so SIMD lane order cannot perturb ties; the
        // symmetrized ring backbone guarantees directed reachability.
        let d = 6;
        let n = 80;
        let data = Matrix::from_vec(
            (0..n * d).map(|x| ((x * 13 + 5) % 97) as f32 - 48.0).collect(),
            n,
            d,
        );
        let mut knn = exact_graph(&data, 6);
        symmetrize_with_ring(&data, &mut knn);
        let idx = SearchIndex::build(&data, &knn, None, 8);
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(1);
        for q in 0..n {
            let row: Vec<f32> = data.row(q).to_vec();
            let (got, stats) = search_nearest(&row, &data, &knn, &idx, 10, n);
            let want = kernels::nearest_k(&row, &data, 10, &mut dists, &mut heap);
            assert!(!stats.fallback, "wide beam must not need the oracle");
            assert_eq!(got, want, "query {q}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let data = Matrix::zeros(0, 4);
        let knn = KnnGraph::empty(0, 3);
        let idx = SearchIndex::build(&data, &knn, None, 8);
        let (out, stats) = search_nearest(&[0.0; 4], &data, &knn, &idx, 3, 8);
        assert!(out.is_empty() && !stats.fallback);

        // n ≤ seeds: every point is a seed, results are exact.
        let data = gaussian_matrix(5, 4, 7);
        let knn = exact_graph(&data, 2);
        let idx = SearchIndex::build(&data, &knn, None, 8);
        assert_eq!(idx.seeds().len(), 5);
        let (out, _) = search_nearest(&data.row(3).to_vec(), &data, &knn, &idx, 2, 8);
        assert_eq!(out[0].0, 3);
        assert_eq!(out[0].1, 0.0);
    }

    #[test]
    fn disconnected_component_falls_back_to_exact() {
        // Points 0..40 carry edges; 40..44 are isolated vertices. The
        // seed cap (4) is below the coarsest level's cluster count
        // (A's supernodes plus 4 singletons), so the stride can keep
        // at most 2 of the 4 isolated points as seeds — with k = n the
        // walk therefore *cannot* reach min(k, n) points and must
        // answer via the exact oracle, never a short result.
        let data = gaussian_matrix(44, 4, 11);
        let full = exact_graph(&data, 4);
        let mut knn = KnnGraph::empty(44, 4);
        for i in 0..40 {
            knn.neighbors[i] =
                full.neighbors[i].iter().copied().filter(|&(id, _)| id < 40).collect();
        }
        let idx = SearchIndex::build(&data, &knn, None, 4);
        let mut dists = Vec::new();
        let mut heap = BoundedMaxHeap::new(1);
        let row: Vec<f32> = data.row(42).to_vec();
        let (got, stats) = search_nearest(&row, &data, &knn, &idx, 44, 8);
        let want = kernels::nearest_k(&row, &data, 44, &mut dists, &mut heap);
        assert!(stats.fallback, "unreachable points must trigger the exact fallback");
        assert_eq!(got, want);
        assert_eq!(got[0], (42, 0.0));
    }

    #[test]
    fn seed_fallbacks_grid_then_stride() {
        // Edgeless KNN graph: no hierarchy possible.
        let data = gaussian_matrix(200, 2, 3);
        let knn = KnnGraph::empty(200, 4);
        let grid = GridIndex::build(&data, 8);
        let idx = SearchIndex::build(&data, &knn, Some(&grid), 16);
        assert_eq!(idx.source(), SeedSource::Grid);
        assert!(!idx.seeds().is_empty() && idx.seeds().len() <= 16);

        let idx = SearchIndex::build(&data, &knn, None, 16);
        assert_eq!(idx.source(), SeedSource::Random);
        assert!(!idx.seeds().is_empty() && idx.seeds().len() <= 16);
        // Edgeless graph: nothing beyond the seeds is reachable, so a
        // k above the seed count must fall back, not come up short.
        let (out, stats) = search_nearest(&data.row(0).to_vec(), &data, &knn, &idx, 20, 16);
        assert_eq!(out.len(), 20);
        assert!(stats.fallback);
    }

    #[test]
    fn search_is_deterministic() {
        let data = gaussian_matrix(300, 6, 5);
        let knn = exact_graph(&data, 8);
        let idx = SearchIndex::build(&data, &knn, None, 12);
        let q: Vec<f32> = data.row(123).iter().map(|v| v + 0.01).collect();
        let (a, sa) = search_nearest(&q, &data, &knn, &idx, 7, 24);
        let (b, sb) = search_nearest(&q, &data, &knn, &idx, 7, 24);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn index_metadata_is_small_and_consistent() {
        let data = gaussian_matrix(1000, 8, 9);
        let knn = exact_graph(&data, 6);
        let idx = SearchIndex::build(&data, &knn, None, 32);
        assert!(idx.seeds().len() <= 32, "seed cap violated: {}", idx.seeds().len());
        assert!(idx.levels() >= 1, "1000 → 32 needs at least one level");
        // Maps chain: level 0 maps all 1000 points, each next level
        // maps the previous level's cluster count.
        let mut prev = 1000usize;
        for m in idx.maps() {
            assert_eq!(m.len(), prev);
            prev = (*m.iter().max().unwrap() + 1) as usize;
        }
        for w in idx.seeds().windows(2) {
            assert!(w[0] < w[1], "seeds must be sorted and distinct");
        }
    }
}
