//! Random projection trees (Dasgupta & Freund, 2008) — the paper's
//! starting point for approximate KNN graph construction.
//!
//! Every internal node splits its subspace by the hyperplane equidistant
//! to two randomly sampled points; leaves hold ≤ `leaf_size` points.
//! Points in the same leaf become mutual neighbor *candidates*; a
//! forest of `n_trees` unions its candidates. Accuracy grows with
//! `n_trees` at linear cost — the dilemma the paper breaks with
//! neighbor exploring ([`crate::knn::explore`]).

use crate::data::matrix::Matrix;
use crate::kernels::{self, dot, sqdist};
use crate::knn::{KnnGraph, ScanScratch};
use crate::util::pool;
use crate::util::rng::Rng;

/// RP-forest build parameters.
#[derive(Clone, Debug)]
pub struct RpForestConfig {
    /// Number of trees (accuracy knob).
    pub n_trees: usize,
    /// Max points per leaf.
    pub leaf_size: usize,
    /// Leaves visited per query per tree (Annoy-style priority search;
    /// 1 = own leaf only). Extra leaves cross partition boundaries so
    /// neighbor exploring can escape single-tree leaf cliques.
    pub search_leaves: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for RpForestConfig {
    fn default() -> Self {
        RpForestConfig { n_trees: 8, leaf_size: 32, search_leaves: 3, threads: 0, seed: 0x8f0 }
    }
}

/// One node of an RP-tree, flattened into arrays for cache friendliness.
enum Node {
    /// Hyperplane split: normal index into `normals`, offset, children.
    Split { normal: u32, offset: f32, left: u32, right: u32 },
    /// Leaf: range into `leaf_points`.
    Leaf { start: u32, len: u32 },
}

/// A single random projection tree over the dataset.
pub struct RpTree {
    nodes: Vec<Node>,
    normals: Vec<f32>, // n_splits × d
    leaf_points: Vec<u32>,
    d: usize,
}

impl RpTree {
    /// Build a tree over all points of `data`.
    pub fn build(data: &Matrix, leaf_size: usize, rng: &mut Rng) -> Self {
        let mut t = RpTree {
            nodes: Vec::new(),
            normals: Vec::new(),
            leaf_points: Vec::new(),
            d: data.d(),
        };
        let mut idx: Vec<u32> = (0..data.n() as u32).collect();
        t.build_rec(data, &mut idx, leaf_size.max(2), rng);
        t
    }

    fn build_rec(&mut self, data: &Matrix, idx: &mut [u32], leaf_size: usize, rng: &mut Rng) -> u32 {
        let node_id = self.nodes.len() as u32;
        if idx.len() <= leaf_size {
            let start = self.leaf_points.len() as u32;
            self.leaf_points.extend_from_slice(idx);
            self.nodes.push(Node::Leaf { start, len: idx.len() as u32 });
            return node_id;
        }
        // Pick two distinct random points; hyperplane = perpendicular
        // bisector of the segment between them.
        let (mut a, mut b) = (0usize, 0usize);
        for _ in 0..16 {
            a = idx[rng.below(idx.len())] as usize;
            b = idx[rng.below(idx.len())] as usize;
            if a != b && sqdist(data.row(a), data.row(b)) > 0.0 {
                break;
            }
        }
        if a == b || sqdist(data.row(a), data.row(b)) == 0.0 {
            // Degenerate (duplicated points): make a leaf.
            let start = self.leaf_points.len() as u32;
            self.leaf_points.extend_from_slice(idx);
            self.nodes.push(Node::Leaf { start, len: idx.len() as u32 });
            return node_id;
        }
        let d = self.d;
        let normal_idx = (self.normals.len() / d) as u32;
        let ra = data.row(a);
        let rb = data.row(b);
        // normal = a - b; offset = normal · midpoint.
        let mut offset = 0f32;
        for k in 0..d {
            let nk = ra[k] - rb[k];
            self.normals.push(nk);
            offset += nk * 0.5 * (ra[k] + rb[k]);
        }
        let normal = &self.normals[normal_idx as usize * d..(normal_idx as usize + 1) * d].to_vec();
        // Partition in place.
        let mut lo = 0usize;
        let mut hi = idx.len();
        while lo < hi {
            let p = idx[lo] as usize;
            if dot(data.row(p), normal) < offset {
                lo += 1;
            } else {
                hi -= 1;
                idx.swap(lo, hi);
            }
        }
        // Guard against empty side (can happen with heavy duplicates):
        // force a median-ish split.
        if lo == 0 || lo == idx.len() {
            lo = idx.len() / 2;
        }
        self.nodes.push(Node::Split { normal: normal_idx, offset, left: 0, right: 0 });
        let (l_idx, r_idx) = idx.split_at_mut(lo);
        let left = self.build_rec(data, l_idx, leaf_size, rng);
        let right = self.build_rec(data, r_idx, leaf_size, rng);
        match &mut self.nodes[node_id as usize] {
            Node::Split { left: l, right: r, .. } => {
                *l = left;
                *r = right;
            }
            _ => unreachable!(),
        }
        node_id
    }

    /// Leaf candidate ids for a query vector.
    pub fn leaf_for(&self, q: &[f32]) -> &[u32] {
        let mut cur = 0u32;
        loop {
            match &self.nodes[cur as usize] {
                Node::Leaf { start, len } => {
                    return &self.leaf_points[*start as usize..(*start + *len) as usize];
                }
                Node::Split { normal, offset, left, right } => {
                    let n = &self.normals[*normal as usize * self.d..(*normal as usize + 1) * self.d];
                    cur = if dot(q, n) < *offset { *left } else { *right };
                }
            }
        }
    }

    /// Annoy-style priority search: visit up to `max_leaves` leaves in
    /// order of hyperplane-margin distance, calling `visit` on each
    /// candidate slice. Crosses partition boundaries, unlike `leaf_for`.
    pub fn search_leaves(&self, q: &[f32], max_leaves: usize, visit: &mut impl FnMut(&[u32])) {
        // Min-heap on margin distance via Reverse-ordered f32 bits.
        let mut heap: std::collections::BinaryHeap<(std::cmp::Reverse<u32>, u32)> =
            std::collections::BinaryHeap::new();
        let key = |margin: f32| std::cmp::Reverse(margin.max(0.0).to_bits());
        heap.push((key(0.0), 0));
        let mut visited = 0usize;
        while let Some((_, mut cur)) = heap.pop() {
            loop {
                match &self.nodes[cur as usize] {
                    Node::Leaf { start, len } => {
                        visit(&self.leaf_points[*start as usize..(*start + *len) as usize]);
                        visited += 1;
                        break;
                    }
                    Node::Split { normal, offset, left, right } => {
                        let nvec =
                            &self.normals[*normal as usize * self.d..(*normal as usize + 1) * self.d];
                        let margin = dot(q, nvec) - *offset;
                        let (near, far) = if margin < 0.0 { (*left, *right) } else { (*right, *left) };
                        heap.push((key(margin.abs()), far));
                        cur = near;
                    }
                }
            }
            if visited >= max_leaves {
                break;
            }
        }
    }
}

/// Build an approximate KNN graph from an RP-forest: each point's
/// candidates are the union of its leaves across trees.
pub fn rp_forest_knn(data: &Matrix, k: usize, cfg: &RpForestConfig) -> KnnGraph {
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let base = Rng::new(cfg.seed);
    // Trees build independently in parallel.
    let trees: Vec<RpTree> = {
        let mut trees: Vec<Option<RpTree>> = (0..cfg.n_trees).map(|_| None).collect();
        std::thread::scope(|s| {
            for (t, slot) in trees.iter_mut().enumerate() {
                let mut rng = base.split(t as u64);
                let data = &data;
                let leaf = cfg.leaf_size;
                s.spawn(move || {
                    *slot = Some(RpTree::build(data, leaf, &mut rng));
                });
            }
        });
        trees.into_iter().map(|t| t.unwrap()).collect()
    };

    // Per-worker scratch reused across every query a worker handles,
    // so the scan loop allocates nothing per node.
    let n = data.n();
    let neighbors = pool::parallel_map_with(
        n,
        threads,
        |_worker| ScanScratch::new(n, k),
        |s, i| {
            let q = data.row(i);
            s.begin(k, i as u32);
            // Dedup candidates repeated across trees/leaves before
            // paying for a distance computation (§Perf).
            let ScanScratch { seen, heap, cand, dist } = s;
            for tree in &trees {
                tree.search_leaves(q, cfg.search_leaves.max(1), &mut |leaf| {
                    for &c in leaf {
                        if seen.insert(c) {
                            cand.push(c);
                        }
                    }
                });
            }
            // Whole candidate set in one batched SIMD pass.
            kernels::sqdist_batch(q, data, cand, dist);
            for (&c, &d) in cand.iter().zip(dist.iter()) {
                if d < heap.threshold() {
                    heap.push(c, d, true);
                }
            }
            heap.drain_sorted_pairs()
        },
    );
    KnnGraph { neighbors, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn leaf_sizes_respected() {
        let (m, _) = gaussian_mixture(500, 10, 5, 0.2, 1);
        let mut rng = Rng::new(2);
        let t = RpTree::build(&m, 16, &mut rng);
        let mut total = 0usize;
        for node in &t.nodes {
            if let Node::Leaf { len, .. } = node {
                // Degenerate duplicate leaves may exceed; gaussian data won't.
                assert!(*len <= 16, "leaf of size {len}");
                total += *len as usize;
            }
        }
        assert_eq!(total, 500); // every point in exactly one leaf
    }

    #[test]
    fn every_point_reaches_its_own_leaf() {
        let (m, _) = gaussian_mixture(200, 8, 4, 0.2, 3);
        let mut rng = Rng::new(4);
        let t = RpTree::build(&m, 8, &mut rng);
        for i in 0..m.n() {
            let leaf = t.leaf_for(m.row(i));
            assert!(leaf.contains(&(i as u32)), "point {i} missing from its leaf");
        }
    }

    #[test]
    fn recall_grows_with_trees() {
        let (m, _) = gaussian_mixture(600, 16, 6, 0.3, 5);
        let truth = exact_knn(&m, 10, 4);
        let r1 = rp_forest_knn(&m, 10, &RpForestConfig { n_trees: 1, leaf_size: 24, threads: 2, seed: 6, ..Default::default() })
            .recall_against(&truth);
        let r8 = rp_forest_knn(&m, 10, &RpForestConfig { n_trees: 12, leaf_size: 24, threads: 2, seed: 6, ..Default::default() })
            .recall_against(&truth);
        assert!(r8 > r1, "recall 12 trees {r8} <= 1 tree {r1}");
        assert!(r8 > 0.5, "12-tree recall too low: {r8}");
    }

    #[test]
    fn graph_invariants_hold() {
        let (m, _) = gaussian_mixture(300, 12, 3, 0.2, 7);
        let g = rp_forest_knn(&m, 8, &RpForestConfig::default());
        g.check_invariants().unwrap();
    }

    #[test]
    fn handles_duplicate_points() {
        // All points identical: degenerate splits must not loop forever.
        let m = Matrix::from_vec(vec![1.0; 50 * 4], 50, 4);
        let g = rp_forest_knn(&m, 5, &RpForestConfig { n_trees: 2, leaf_size: 8, threads: 1, seed: 1, ..Default::default() });
        g.check_invariants().unwrap();
        assert!(g.neighbors.iter().all(|nb| nb.len() == 5));
    }
}
