//! NN-Descent (Dong, Moses & Li, WWW 2011) — the neighbor-exploring
//! baseline of Fig 2.
//!
//! Starts from a *random* graph (unlike LargeVis which starts from an
//! RP-forest) and iterates local joins between each node's new/old
//! neighbors and reverse neighbors until convergence. Efficient at low
//! dimension, slower to converge at high dimension — the gap the paper
//! exploits.

use crate::data::matrix::Matrix;
use crate::kernels::{self, sqdist};
use crate::knn::KnnGraph;
use crate::util::heap::BoundedMaxHeap;
use crate::util::pool;
use crate::util::rng::Rng;

/// NN-Descent parameters.
#[derive(Clone, Debug)]
pub struct NnDescentConfig {
    /// Max iterations.
    pub max_iters: usize,
    /// Sample rate ρ for the local join (1.0 = full join).
    pub sample_rate: f64,
    /// Early-stop when updates per node fall below `delta * K * N`.
    pub delta: f64,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for NnDescentConfig {
    fn default() -> Self {
        NnDescentConfig { max_iters: 10, sample_rate: 1.0, delta: 0.001, threads: 0, seed: 0x4e4e }
    }
}

/// Run NN-Descent to build an approximate KNN graph.
pub fn nn_descent(data: &Matrix, k: usize, cfg: &NnDescentConfig) -> KnnGraph {
    let n = data.n();
    let threads = if cfg.threads == 0 { pool::default_threads() } else { cfg.threads };
    let base_rng = Rng::new(cfg.seed);

    // Random initialization: k random neighbors per node.
    let mut heaps: Vec<BoundedMaxHeap> = pool::parallel_map(n, threads, |i| {
        let mut rng = base_rng.split(i as u64);
        let mut h = BoundedMaxHeap::new(k);
        while h.len() < k.min(n - 1) {
            let j = rng.below(n);
            if j != i {
                h.push(j as u32, sqdist(data.row(i), data.row(j)), true);
            }
        }
        h
    });

    let sample_k = ((k as f64 * cfg.sample_rate).ceil() as usize).max(1);

    for _iter in 0..cfg.max_iters {
        // Build sampled new/old lists and reverse lists.
        let mut new_fwd: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_fwd: Vec<Vec<u32>> = vec![Vec::new(); n];
        {
            let mut rng = base_rng.split(0xFFFF ^ _iter as u64);
            for (i, h) in heaps.iter_mut().enumerate() {
                let cands = h.as_mut_slice();
                // Sample up to sample_k flagged (new) candidates; clear flags.
                let mut new_ids: Vec<usize> =
                    cands.iter().enumerate().filter(|(_, c)| c.flag).map(|(idx, _)| idx).collect();
                rng.shuffle(&mut new_ids);
                new_ids.truncate(sample_k);
                for (idx, c) in cands.iter().enumerate() {
                    if c.flag && new_ids.contains(&idx) {
                        new_fwd[i].push(c.id);
                    } else if !c.flag {
                        old_fwd[i].push(c.id);
                    }
                }
                for &idx in &new_ids {
                    cands[idx].flag = false;
                }
            }
        }
        // Reverse lists (sampled).
        let mut new_rev: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_rev: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            for &j in &new_fwd[i] {
                new_rev[j as usize].push(i as u32);
            }
            for &j in &old_fwd[i] {
                old_rev[j as usize].push(i as u32);
            }
        }
        {
            let mut rng = base_rng.split(0xABCD ^ _iter as u64);
            for lists in [&mut new_rev, &mut old_rev] {
                for l in lists.iter_mut() {
                    if l.len() > sample_k {
                        rng.shuffle(l);
                        l.truncate(sample_k);
                    }
                }
            }
        }

        // Local join: candidates of node i = new[i] ∪ new_rev[i] joined
        // against (new ∪ old ∪ reverses). Collect updates, then apply —
        // simple two-phase scheme to stay deterministic per iteration.
        // Each anchor `a` evaluates its partners through the batched
        // SIMD kernel; the id/distance/list buffers are all per-worker
        // scratch (no per-node allocation beyond the returned updates).
        let updates: Vec<Vec<(u32, u32, f32)>> = pool::parallel_map_with(
            n,
            threads,
            |_worker| {
                (Vec::<u32>::new(), Vec::<f32>::new(), Vec::<u32>::new(), Vec::<u32>::new())
            },
            |(cand, dist, news, olds), i| {
                let mut ups = Vec::new();
                news.clear();
                news.extend_from_slice(&new_fwd[i]);
                news.extend_from_slice(&new_rev[i]);
                olds.clear();
                olds.extend_from_slice(&old_fwd[i]);
                olds.extend_from_slice(&old_rev[i]);
                news.sort_unstable();
                news.dedup();
                olds.sort_unstable();
                olds.dedup();
                for ai in 0..news.len() {
                    let a = news[ai];
                    // new-new partners (news is sorted + deduped, so the
                    // tail past ai cannot repeat a), then new-old ones.
                    cand.clear();
                    cand.extend(news[ai + 1..].iter().copied());
                    cand.extend(olds.iter().copied().filter(|&b| b != a));
                    kernels::sqdist_batch(data.row(a as usize), data, cand, dist);
                    for (&b, &d) in cand.iter().zip(dist.iter()) {
                        ups.push((a, b, d));
                    }
                }
                ups
            },
        );

        let mut changed = 0usize;
        for ups in &updates {
            for &(a, b, d) in ups {
                if d < heaps[a as usize].threshold() && heaps[a as usize].push(b, d, true) {
                    changed += 1;
                }
                if d < heaps[b as usize].threshold() && heaps[b as usize].push(a, d, true) {
                    changed += 1;
                }
            }
        }
        if (changed as f64) < cfg.delta * (n * k) as f64 {
            break;
        }
    }

    let neighbors = heaps
        .into_iter()
        .map(|h| h.into_sorted().iter().map(|c| (c.id, c.dist)).collect())
        .collect();
    KnnGraph { neighbors, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::knn::bruteforce::exact_knn;

    #[test]
    fn converges_to_high_recall_low_dim() {
        let (m, _) = gaussian_mixture(500, 8, 4, 0.2, 1);
        let truth = exact_knn(&m, 10, 4);
        let g = nn_descent(&m, 10, &NnDescentConfig { threads: 2, ..Default::default() });
        let recall = g.recall_against(&truth);
        assert!(recall > 0.90, "NN-Descent recall {recall}");
        g.check_invariants().unwrap();
    }

    #[test]
    fn more_iters_not_worse() {
        let (m, _) = gaussian_mixture(300, 12, 3, 0.2, 2);
        let truth = exact_knn(&m, 8, 2);
        let one = nn_descent(&m, 8, &NnDescentConfig { max_iters: 1, threads: 2, ..Default::default() })
            .recall_against(&truth);
        let five = nn_descent(&m, 8, &NnDescentConfig { max_iters: 5, threads: 2, ..Default::default() })
            .recall_against(&truth);
        assert!(five >= one - 0.02, "iters hurt: 1->{one}, 5->{five}");
    }

    #[test]
    fn tiny_dataset() {
        let (m, _) = gaussian_mixture(12, 4, 2, 0.2, 3);
        let g = nn_descent(&m, 5, &NnDescentConfig { threads: 1, ..Default::default() });
        g.check_invariants().unwrap();
    }
}
