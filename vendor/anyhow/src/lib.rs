//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace
//! vendors the small slice of `anyhow` the codebase actually uses:
//!
//! * [`Error`] — a context-chain error type (`Display` shows the
//!   outermost message, `{:#}` the full chain joined by `": "`, `Debug`
//!   an anyhow-style "Caused by" listing),
//! * [`Result`] with the `E = Error` default,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   *and* `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! * `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Semantics intentionally mirror the real crate for these paths; the
//! backtrace/downcast machinery is omitted.

use std::fmt;

/// A context-chain error. Frame 0 is the outermost (most recently
/// attached) message; later frames are underlying causes.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost cause preserved).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real `anyhow::Error`, this type deliberately does NOT
// implement `std::error::Error`, which keeps the blanket `From` below
// coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    /// Attach a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = anyhow!("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing key {}", "k")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key k");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert_eq!(format!("{}", check(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", check(200).unwrap_err()), "too big");
    }

    #[test]
    fn chain_order_outermost_first() {
        let e = anyhow!("cause").context("mid").context("top");
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames, vec!["top", "mid", "cause"]);
        assert_eq!(e.root_cause(), "cause");
    }
}
