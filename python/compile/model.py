"""L2: the batched LargeVis SGD step as a single JAX computation.

``largevis_step`` is the full update for one batch of sampled edges:
gather the touched embeddings from the table, run the L1 gradient
kernel, scatter-add the scaled updates back. Lowered once by aot.py;
the rust coordinator then drives it via PJRT with integer index batches
— Python never runs at layout time.

Duplicate indices within a batch are handled by the scatter-add
semantics of ``.at[].add`` (contributions sum, matching sequential SGD
up to reordering).
"""

import jax.numpy as jnp

from compile.kernels.largevis_grad import largevis_grad
from compile.kernels.pdist import pdist  # re-exported for aot


def largevis_step(y, idx_i, idx_j, idx_neg, rho, gamma):
    """One batched SGD step over the embedding table.

    Args:
      y:       [N, s] embedding table (donated by the runtime).
      idx_i:   [B] int32 edge sources.
      idx_j:   [B] int32 edge targets.
      idx_neg: [B, M] int32 negative samples.
      rho:     scalar learning rate.
      gamma:   scalar negative weight.

    Returns:
      [N, s] updated table.
    """
    yi = y[idx_i]           # [B, s]
    yj = y[idx_j]           # [B, s]
    yneg = y[idx_neg]       # [B, M, s]
    gi, gj, gneg = largevis_grad(yi, yj, yneg, gamma, a=1.0)
    rho = jnp.asarray(rho, jnp.float32)
    y = y.at[idx_i].add(rho * gi)
    y = y.at[idx_j].add(rho * gj)
    y = y.at[idx_neg.reshape(-1)].add(rho * gneg.reshape(-1, y.shape[1]))
    return y


def grad_only(yi, yj, yneg, gamma):
    """N-independent gradient artifact (rust does gather/scatter)."""
    return largevis_grad(yi, yj, yneg, gamma, a=1.0)
