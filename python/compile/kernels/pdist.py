"""L1 Pallas kernel: tiled squared-Euclidean pairwise distances.

Used by the exact-KNN ground-truth path: the rust coordinator streams
[TILE, d] query/corpus blocks through this kernel and keeps a bounded
heap of the results.

TPU framing: ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b turns the O(Q.R.d)
distance computation into a matmul — MXU work with f32 accumulation;
the row-norm terms are VPU epilogue. Tiles of 256x256 over d=128 keep
each operand slab at 128 KiB in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pdist_kernel(xa_ref, xb_ref, out_ref):
    xa = xa_ref[...]
    xb = xb_ref[...]
    na = jnp.sum(xa * xa, axis=-1)[:, None]
    nb = jnp.sum(xb * xb, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        xa, xb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = jnp.maximum(na + nb - 2.0 * cross, 0.0)


@jax.jit
def pdist(xa, xb):
    """Squared distances between all rows of xa [Q,d] and xb [R,d]."""
    q, d = xa.shape
    r, _ = xb.shape
    return pl.pallas_call(
        _pdist_kernel,
        out_shape=jax.ShapeDtypeStruct((q, r), jnp.float32),
        interpret=True,
    )(xa, xb)
