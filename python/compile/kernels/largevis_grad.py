"""L1 Pallas kernel: fused batched LargeVis edge gradient.

The SGD hot-spot of the paper — for a tile of B edges with M negatives
each, compute the attractive and repulsive gradients of

    O = log f(||yi-yj||) + sum_m gamma log(1 - f(||yi-yn_m||)),
    f(x) = 1/(1 + a x^2)

fused in one VMEM-resident pass (no intermediate HBM traffic).

TPU framing (DESIGN.md §Hardware-Adaptation): the computation is
elementwise + small-axis reductions — VPU work. We tile the batch
dimension with BlockSpec so each grid step owns a [TILE_B, ...] slab in
VMEM; negatives are kept as a flattened [TILE_B, M*s] lane-dim array so
the lane dimension stays contiguous. interpret=True everywhere (CPU
correctness path; Mosaic lowering is TPU-only).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import CLIP, EPS

# Batch tile per grid step. 256 edges x (1+M) partners x s floats is
# ~14 KiB of VMEM at M=5, s=2 — far under the ~4 MiB/tile budget, so
# the tile size is chosen for grid overhead, not capacity.
TILE_B = 256


def _grad_kernel(yi_ref, yj_ref, yneg_ref, gamma_ref, gi_ref, gj_ref, gneg_ref, *, a, m, s):
    """One batch tile: yi/yj [T,s], yneg [T, M*s] flattened."""
    yi = yi_ref[...]
    yj = yj_ref[...]
    gamma = gamma_ref[0]

    delta = yi - yj
    d2 = jnp.sum(delta * delta, axis=-1, keepdims=True)
    gpos = jnp.clip((-2.0 * a / (1.0 + a * d2)) * delta, -CLIP, CLIP)

    yneg = yneg_ref[...].reshape(yi.shape[0], m, s)
    dneg = yi[:, None, :] - yneg
    d2n = jnp.sum(dneg * dneg, axis=-1, keepdims=True)
    cneg = 2.0 * gamma / ((EPS + d2n) * (1.0 + a * d2n))
    gneg_term = jnp.clip(cneg * dneg, -CLIP, CLIP)

    gi_ref[...] = gpos + jnp.sum(gneg_term, axis=1)
    gj_ref[...] = -gpos
    gneg_ref[...] = (-gneg_term).reshape(yi.shape[0], m * s)


@functools.partial(jax.jit, static_argnames=("a",))
def largevis_grad(yi, yj, yneg, gamma, a=1.0):
    """Pallas-tiled LargeVis gradient.

    Args/returns match ``ref.largevis_grad_ref`` (yneg is [B, M, s]).
    ``gamma`` is a scalar array so it stays a runtime input of the AOT
    artifact (the rust coordinator can change it without recompiling).
    """
    b, s = yi.shape
    _, m, _ = yneg.shape
    assert b % TILE_B == 0 or b < TILE_B, f"B={b} must be < or multiple of {TILE_B}"
    tile = min(TILE_B, b)
    grid = (b // tile,)
    yneg_flat = yneg.reshape(b, m * s)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1)

    gi, gj, gneg_flat = pl.pallas_call(
        functools.partial(_grad_kernel, a=a, m=m, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, m * s), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, s), lambda i: (i, 0)),
            pl.BlockSpec((tile, m * s), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b, s), jnp.float32),
            jax.ShapeDtypeStruct((b, m * s), jnp.float32),
        ],
        interpret=True,
    )(yi, yj, yneg_flat, gamma_arr)
    return gi, gj, gneg_flat.reshape(b, m, s)
