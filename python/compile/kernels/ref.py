"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis), and double as readable documentation of the math.

Gradient convention matches the rust Hogwild engine
(`rust/src/vis/objective.rs`): gradients are of the *maximized*
objective, so the update is ``y += rho * grad``.
"""

import jax.numpy as jnp

# Repulsive-singularity guard; must match rust vis::objective::EPS.
EPS = 0.1
# Per-component gradient clip; must match LargeVisConfig::grad_clip.
CLIP = 5.0


def largevis_grad_ref(yi, yj, yneg, gamma, a=1.0):
    """Batched LargeVis gradient for f(x) = 1/(1 + a x^2).

    Args:
      yi:   [B, s] source embeddings.
      yj:   [B, s] positive-target embeddings.
      yneg: [B, M, s] negative-sample embeddings.
      gamma: scalar negative weight.
      a: scale of the probability function.

    Returns:
      (gi, gj, gneg): gradients of the objective w.r.t. yi, yj, yneg
      with shapes matching the inputs. Per-component clipping to
      [-CLIP, CLIP] is applied to each *term* (positive term and each
      negative term separately), exactly as the reference C++ and our
      rust engine do.
    """
    delta = yi - yj                                     # [B, s]
    d2 = jnp.sum(delta * delta, axis=-1, keepdims=True)  # [B, 1]
    gpos = jnp.clip((-2.0 * a / (1.0 + a * d2)) * delta, -CLIP, CLIP)

    dneg = yi[:, None, :] - yneg                        # [B, M, s]
    d2n = jnp.sum(dneg * dneg, axis=-1, keepdims=True)  # [B, M, 1]
    cneg = 2.0 * gamma / ((EPS + d2n) * (1.0 + a * d2n))
    gneg_term = jnp.clip(cneg * dneg, -CLIP, CLIP)      # [B, M, s]

    gi = gpos + jnp.sum(gneg_term, axis=1)              # [B, s]
    gj = -gpos
    gneg = -gneg_term
    return gi, gj, gneg


def pdist_ref(xa, xb):
    """Squared Euclidean distances between rows of xa [Q,d] and xb [R,d].

    Uses the matmul reformulation ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b
    (clamped at 0 against rounding), the same schedule the Pallas kernel
    uses to target the MXU.
    """
    na = jnp.sum(xa * xa, axis=-1)[:, None]   # [Q, 1]
    nb = jnp.sum(xb * xb, axis=-1)[None, :]   # [1, R]
    cross = xa @ xb.T                          # [Q, R]
    return jnp.maximum(na + nb - 2.0 * cross, 0.0)
