"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/.

HLO text (NOT serialized protos) is the interchange format: jax >= 0.5
emits 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (shapes baked at trace time, listed in the manifest):
  grad_kernel.hlo.txt    (yi[B,s], yj[B,s], yneg_flat[B,M*s], gamma[1])
                         -> (gi, gj, gneg_flat)   B=1024, M=5, s=2
  largevis_step.hlo.txt  (y[N,s], i[B], j[B], neg[B,M], rho[], gamma[])
                         -> y'                     N=10000
  pdist.hlo.txt          (xa[256,100], xb[256,100]) -> [256,256]

Run: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Baked artifact shapes — keep in sync with rust/src/runtime/mod.rs.
BATCH = 1024
NEGATIVES = 5
DIM = 2
STEP_N = 10_000
PDIST_TILE = 256
PDIST_D = 100


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_grad_kernel():
    f32 = jnp.float32
    spec = [
        jax.ShapeDtypeStruct((BATCH, DIM), f32),
        jax.ShapeDtypeStruct((BATCH, DIM), f32),
        jax.ShapeDtypeStruct((BATCH, NEGATIVES, DIM), f32),
        jax.ShapeDtypeStruct((), f32),
    ]
    return jax.jit(model.grad_only).lower(*spec)


def lower_largevis_step():
    f32, i32 = jnp.float32, jnp.int32
    spec = [
        jax.ShapeDtypeStruct((STEP_N, DIM), f32),
        jax.ShapeDtypeStruct((BATCH,), i32),
        jax.ShapeDtypeStruct((BATCH,), i32),
        jax.ShapeDtypeStruct((BATCH, NEGATIVES), i32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((), f32),
    ]
    return jax.jit(model.largevis_step, donate_argnums=(0,)).lower(*spec)


def lower_pdist():
    f32 = jnp.float32
    spec = [
        jax.ShapeDtypeStruct((PDIST_TILE, PDIST_D), f32),
        jax.ShapeDtypeStruct((PDIST_TILE, PDIST_D), f32),
    ]
    return jax.jit(model.pdist).lower(*spec)


ARTIFACTS = {
    "grad_kernel": lower_grad_kernel,
    "largevis_step": lower_largevis_step,
    "pdist": lower_pdist,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "batch": BATCH,
        "negatives": NEGATIVES,
        "dim": DIM,
        "step_n": STEP_N,
        "pdist_tile": PDIST_TILE,
        "pdist_d": PDIST_D,
        "artifacts": {},
    }
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = os.path.basename(path)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
