"""Kernel-vs-reference correctness — the core L1 signal.

Exhaustive fixed cases plus hypothesis sweeps over shapes and value
ranges. Everything runs on CPU with interpret=True.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.largevis_grad import TILE_B, largevis_grad
from compile.kernels.pdist import pdist
from compile.kernels.ref import CLIP, EPS, largevis_grad_ref, pdist_ref


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize("b", [8, 64, TILE_B, 2 * TILE_B])
@pytest.mark.parametrize("m", [1, 5])
def test_grad_matches_ref(b, m):
    rng = np.random.default_rng(b * 31 + m)
    yi, yj = _rand(rng, (b, 2)), _rand(rng, (b, 2))
    yn = _rand(rng, (b, m, 2))
    got = largevis_grad(yi, yj, yn, 7.0)
    want = largevis_grad_ref(yi, yj, yn, 7.0)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


def test_grad_gamma_scales_negative_term():
    rng = np.random.default_rng(1)
    yi, yj = _rand(rng, (64, 2)), _rand(rng, (64, 2))
    yn = _rand(rng, (64, 5, 2))
    _, _, gneg1 = largevis_grad(yi, yj, yn, 1.0)
    _, _, gneg3 = largevis_grad(yi, yj, yn, 3.0)
    # Below the clip threshold the negative gradient is linear in gamma.
    mask = np.abs(np.asarray(gneg3)) < CLIP - 1e-3
    np.testing.assert_allclose(
        np.asarray(gneg3)[mask], 3.0 * np.asarray(gneg1)[mask], rtol=1e-4, atol=1e-6
    )


def test_grad_zero_distance_is_finite():
    """Coincident points must not produce NaN/inf (EPS guard)."""
    yi = jnp.zeros((8, 2), jnp.float32)
    got = largevis_grad(yi, yi, jnp.zeros((8, 5, 2), jnp.float32), 7.0)
    for g in got:
        assert np.all(np.isfinite(np.asarray(g)))


def test_grad_attracts_and_repels():
    """Positive gradient pulls i toward j; negatives push i away."""
    yi = jnp.asarray([[1.0, 0.0]], jnp.float32)
    yj = jnp.asarray([[-1.0, 0.0]], jnp.float32)
    yn = jnp.asarray([[[0.5, 0.0]]], jnp.float32)
    gi, gj, gneg = largevis_grad(yi, yj, yn, 7.0)
    # Attraction dominates along x for this geometry? Check signs of terms:
    # gj = -gpos must point from j toward i (positive x).
    assert float(gj[0, 0]) > 0.0
    # The negative at x=0.5 is pushed away from i (negative x direction).
    assert float(gneg[0, 0, 0]) < 0.0


def test_grad_clip_applied():
    """Huge coordinates -> per-component clip at +/-CLIP."""
    yi = jnp.asarray([[1e3, 1e3]], jnp.float32)
    yj = jnp.asarray([[-1e3, -1e3]], jnp.float32)
    yn = jnp.full((1, 5, 2), 1e-4, jnp.float32)
    gi, gj, gneg = largevis_grad(yi, yj, yn, 1e6)
    for g in (gi, gj, gneg):
        assert np.max(np.abs(np.asarray(g))) <= CLIP + 1e-5


@settings(max_examples=30, deadline=None)
@given(
    b=st.sampled_from([4, 16, 128]),
    m=st.integers(1, 8),
    s=st.sampled_from([2, 3]),
    scale=st.floats(1e-3, 1e2),
    gamma=st.floats(0.1, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_hypothesis_sweep(b, m, s, scale, gamma, seed):
    rng = np.random.default_rng(seed)
    yi, yj = _rand(rng, (b, s), scale), _rand(rng, (b, s), scale)
    yn = _rand(rng, (b, m, s), scale)
    got = largevis_grad(yi, yj, yn, gamma)
    want = largevis_grad_ref(yi, yj, yn, gamma)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)
        assert np.all(np.isfinite(np.asarray(g)))


@pytest.mark.parametrize("q,r,d", [(8, 8, 4), (256, 256, 100), (32, 128, 64)])
def test_pdist_matches_ref(q, r, d):
    rng = np.random.default_rng(q + r + d)
    xa, xb = _rand(rng, (q, d)), _rand(rng, (r, d))
    np.testing.assert_allclose(pdist(xa, xb), pdist_ref(xa, xb), rtol=1e-4, atol=1e-3)


def test_pdist_self_diagonal_zero():
    rng = np.random.default_rng(3)
    xa = _rand(rng, (64, 16))
    dmat = np.asarray(pdist(xa, xa))
    np.testing.assert_allclose(np.diag(dmat), 0.0, atol=1e-3)
    assert np.all(dmat >= 0.0)


def test_pdist_matches_naive_loop():
    rng = np.random.default_rng(4)
    xa, xb = _rand(rng, (5, 7)), _rand(rng, (6, 7))
    naive = np.zeros((5, 6), np.float32)
    for i in range(5):
        for j in range(6):
            diff = np.asarray(xa[i]) - np.asarray(xb[j])
            naive[i, j] = float(diff @ diff)
    np.testing.assert_allclose(pdist(xa, xb), naive, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(1, 64),
    r=st.integers(1, 64),
    d=st.integers(1, 128),
    scale=st.floats(1e-2, 1e2),
    seed=st.integers(0, 2**31 - 1),
)
def test_pdist_hypothesis_sweep(q, r, d, scale, seed):
    rng = np.random.default_rng(seed)
    xa, xb = _rand(rng, (q, d), scale), _rand(rng, (r, d), scale)
    got = np.asarray(pdist(xa, xb))
    want = np.asarray(pdist_ref(xa, xb))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2 * scale * scale)
    assert np.all(got >= 0.0)


def test_eps_matches_rust_constant():
    """EPS/CLIP here must stay in sync with rust vis::objective."""
    assert EPS == pytest.approx(0.1)
    assert CLIP == pytest.approx(5.0)
