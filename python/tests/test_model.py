"""L2 model tests: the batched SGD step semantics and AOT lowering."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import largevis_grad_ref


def _setup(n=50, b=16, m=3, seed=0):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=(n, 2)) * 0.01, jnp.float32)
    i = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)
    j = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)
    neg = jnp.asarray(rng.integers(0, n, size=(b, m)), jnp.int32)
    return y, i, j, neg


def test_step_matches_manual_scatter():
    y, i, j, neg = _setup()
    rho, gamma = 0.3, 7.0
    got = model.largevis_step(y, i, j, neg, rho, gamma)

    gi, gj, gneg = largevis_grad_ref(y[i], y[j], y[neg], gamma)
    want = np.asarray(y).copy()
    np.add.at(want, np.asarray(i), rho * np.asarray(gi))
    np.add.at(want, np.asarray(j), rho * np.asarray(gj))
    np.add.at(
        want,
        np.asarray(neg).reshape(-1),
        rho * np.asarray(gneg).reshape(-1, 2),
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_step_only_touched_rows_change():
    y, i, j, neg = _setup(n=100, b=4, m=2, seed=1)
    got = np.asarray(model.largevis_step(y, i, j, neg, 1.0, 7.0))
    touched = set(np.asarray(i)) | set(np.asarray(j)) | set(np.asarray(neg).reshape(-1))
    for v in range(100):
        if v not in touched:
            np.testing.assert_array_equal(got[v], np.asarray(y)[v])


def test_step_duplicate_indices_accumulate():
    """Same edge twice in a batch => double the update of once."""
    y, _, _, _ = _setup(n=10, seed=2)
    i1 = jnp.asarray([1], jnp.int32)
    j1 = jnp.asarray([2], jnp.int32)
    neg1 = jnp.asarray([[3]], jnp.int32)
    i2 = jnp.asarray([1, 1], jnp.int32)
    j2 = jnp.asarray([2, 2], jnp.int32)
    neg2 = jnp.asarray([[3], [3]], jnp.int32)
    once = np.asarray(model.largevis_step(y, i1, j1, neg1, 0.5, 7.0)) - np.asarray(y)
    twice = np.asarray(model.largevis_step(y, i2, j2, neg2, 0.5, 7.0)) - np.asarray(y)
    np.testing.assert_allclose(twice, 2.0 * once, rtol=1e-4, atol=1e-7)


def test_step_improves_objective_on_toy_graph():
    """Repeated steps on a two-clique graph must raise the objective."""
    rng = np.random.default_rng(3)
    n = 12
    edges = [(a, b) for a in range(6) for b in range(6) if a < b]
    edges += [(a + 6, b + 6) for a, b in edges]
    y = jnp.asarray(rng.normal(size=(n, 2)) * 1e-3, jnp.float32)

    def objective(yv):
        o = 0.0
        yv = np.asarray(yv)
        pos = set()
        for a, b in edges:
            d2 = float(((yv[a] - yv[b]) ** 2).sum())
            o += np.log(1.0 / (1.0 + d2))
            pos.add((a, b))
        for a in range(n):
            for b in range(a + 1, n):
                if (a, b) not in pos:
                    d2 = float(((yv[a] - yv[b]) ** 2).sum())
                    o += 7.0 * np.log(max(1.0 - 1.0 / (1.0 + d2), 1e-12))
        return o

    before = objective(y)
    for step in range(60):
        ii = rng.integers(0, len(edges), size=8)
        i = jnp.asarray([edges[k][0] for k in ii], jnp.int32)
        j = jnp.asarray([edges[k][1] for k in ii], jnp.int32)
        neg = jnp.asarray(rng.integers(0, n, size=(8, 5)), jnp.int32)
        rho = 1.0 * (1.0 - step / 60.0)
        y = model.largevis_step(y, i, j, neg, rho, 7.0)
    after = objective(y)
    assert after > before, f"{before} -> {after}"


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_aot_lowering_produces_hlo_text(name):
    text = aot.to_hlo_text(aot.ARTIFACTS[name]())
    assert "HloModule" in text
    # No Mosaic custom-calls may appear (interpret=True requirement).
    assert "tpu_custom_call" not in text and "mosaic" not in text.lower()


def test_manifest_constants_consistent():
    assert aot.BATCH % 256 == 0  # TILE_B divides the batch
    assert aot.DIM == 2
    assert aot.NEGATIVES == 5
