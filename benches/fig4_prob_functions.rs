//! Fig 4: comparing probabilistic functions f(x) for the layout model —
//! `1/(1+ax²)` for several `a` and `1/(1+e^{x²})` — by KNN-classifier
//! accuracy of the resulting layouts.
//!
//! Paper shape: the long-tailed `1/(1+x²)` (a=1) wins; the sigmoid
//! variant crowds and scores clearly lower.

use largevis::bench::{bench_scale, workloads, Table};
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::vis::{layout, LargeVisConfig, ProbFn};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let sets = [("wikidoc-like", 0.0125), ("livejournal-like", 0.01)];
    let mut table = Table::new(
        "Fig 4 — probabilistic functions (KNN accuracy of layout)",
        &["dataset", "n", "prob_fn", "accuracy", "secs"],
    );

    for (name, base) in sets {
        let w = workloads::prepare(name, base * scale, 50, 0xf164);
        let labels = w.dataset.labels.as_ref().expect("labeled dataset");
        eprintln!("[fig4] {name}: n={}", w.graph.n());
        let fns: [(String, ProbFn); 5] = [
            ("1/(1+0.5x^2)".into(), ProbFn::InvQuad { a: 0.5 }),
            ("1/(1+x^2)".into(), ProbFn::InvQuad { a: 1.0 }),
            ("1/(1+2x^2)".into(), ProbFn::InvQuad { a: 2.0 }),
            ("1/(1+4x^2)".into(), ProbFn::InvQuad { a: 4.0 }),
            ("1/(1+exp(x^2))".into(), ProbFn::SigmoidSq),
        ];
        for (label, f) in fns {
            let cfg = LargeVisConfig { prob_fn: f, samples_per_vertex: 2000, ..Default::default() };
            let t0 = std::time::Instant::now();
            let y = layout(&w.graph, &cfg);
            let secs = t0.elapsed().as_secs_f64();
            let acc = knn_accuracy(&y, labels, &KnnEvalConfig { k: 5, sample: 3000, ..Default::default() });
            table.row(&[
                name.into(),
                w.graph.n().to_string(),
                label,
                format!("{acc:.4}"),
                format!("{secs:.2}"),
            ]);
        }
    }
    table.print();
    table.write_tsv("fig4_prob_functions")?;
    Ok(())
}
