//! Fig 6: layout accuracy and running time vs data size (random samples
//! of wikidoc-like), LargeVis vs BH t-SNE (default lr).
//!
//! Paper shape: with default parameters, LargeVis's accuracy holds or
//! improves with size while default-lr t-SNE degrades; the time gap
//! widens with N (O(N) vs O(N log N)).

use largevis::baselines::{bh_tsne, BhTsneConfig};
use largevis::bench::{bench_scale, workloads, Table};
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let fractions = [0.003, 0.006, 0.0125, 0.025];
    let mut table = Table::new(
        "Fig 6 — accuracy and time vs data size (wikidoc-like)",
        &["n", "method", "accuracy", "secs"],
    );

    for frac in fractions {
        let w = workloads::prepare("wikidoc-like", frac * scale, 50, 0xf166);
        let labels = w.dataset.labels.as_ref().unwrap();
        let n = w.graph.n();
        eprintln!("[fig6] n={n}");
        let ecfg = KnnEvalConfig { k: 5, sample: 3000, ..Default::default() };

        let t0 = std::time::Instant::now();
        let y = bh_tsne(&w.graph, &BhTsneConfig { iters: 250, eta: 200.0, ..Default::default() });
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            n.to_string(),
            "tsne(lr=200)".into(),
            format!("{:.4}", knn_accuracy(&y, labels, &ecfg)),
            format!("{secs:.2}"),
        ]);

        let t0 = std::time::Instant::now();
        let y = layout(&w.graph, &LargeVisConfig { samples_per_vertex: 2000, ..Default::default() });
        let secs = t0.elapsed().as_secs_f64();
        table.row(&[
            n.to_string(),
            "largevis(default)".into(),
            format!("{:.4}", knn_accuracy(&y, labels, &ecfg)),
            format!("{secs:.2}"),
        ]);
    }
    table.print();
    table.write_tsv("fig6_scaling")?;
    Ok(())
}
