//! Fig 5: KNN-classifier accuracy of 2D layouts across datasets and
//! visualizers — Symmetric SNE, BH t-SNE with default and tuned
//! learning rates, LINE (2D, first-order), and LargeVis — for several
//! classifier K.
//!
//! Paper shape: LargeVis ≥ t-SNE(optimal lr) ≥ t-SNE(default lr) on
//! large data; LINE-2D far below everything; t-SNE's optimal lr grows
//! with data size while LargeVis uses one default everywhere.

use largevis::baselines::{bh_sne, bh_tsne, BhSneConfig, BhTsneConfig};
use largevis::bench::{bench_scale, workloads, Table};
use largevis::embed::line::{train_line, LineConfig};
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let sets = [
        ("20ng-like", 0.25),
        ("mnist-like", 0.05),
        ("wikidoc-like", 0.0125),
        ("livejournal-like", 0.01),
    ];
    let tsne_iters = 300;
    let classifier_ks = [1usize, 5, 10];
    let mut table = Table::new(
        "Fig 5 — layout quality by KNN classifier accuracy",
        &["dataset", "n", "method", "k=1", "k=5", "k=10", "secs"],
    );

    for (name, base) in sets {
        let w = workloads::prepare(name, base * scale, 50, 0xf165);
        let labels = w.dataset.labels.as_ref().expect("labeled dataset");
        let n = w.graph.n();
        eprintln!("[fig5] {name}: n={n}");

        let eval = |y: &largevis::data::Matrix| -> Vec<String> {
            classifier_ks
                .iter()
                .map(|&k| {
                    let acc = knn_accuracy(
                        y,
                        labels,
                        &KnnEvalConfig { k, sample: 3000, ..Default::default() },
                    );
                    format!("{acc:.4}")
                })
                .collect()
        };
        let mut record = |method: &str, accs: Vec<String>, secs: f64| {
            let mut row = vec![name.to_string(), n.to_string(), method.to_string()];
            row.extend(accs);
            row.push(format!("{secs:.2}"));
            table.row(&row);
        };

        // Symmetric SNE.
        let t0 = std::time::Instant::now();
        let y = bh_sne(&w.graph, &BhSneConfig { iters: tsne_iters, eta: 50.0, ..Default::default() });
        record("sym-sne", eval(&y), t0.elapsed().as_secs_f64());

        // BH t-SNE, default and swept learning rates (the paper tunes η
        // exhaustively; we sweep a grid and report the best as "opt").
        let t0 = std::time::Instant::now();
        let y = bh_tsne(&w.graph, &BhTsneConfig { iters: tsne_iters, eta: 200.0, ..Default::default() });
        record("tsne(lr=200)", eval(&y), t0.elapsed().as_secs_f64());

        let t0 = std::time::Instant::now();
        let mut best: Option<(f64, f32, Vec<String>)> = None;
        for eta in [200.0f32, 800.0, 2400.0] {
            let y = bh_tsne(&w.graph, &BhTsneConfig { iters: tsne_iters, eta, ..Default::default() });
            let accs = eval(&y);
            let score: f64 = accs[1].parse().unwrap();
            if best.as_ref().map(|(s, _, _)| score > *s).unwrap_or(true) {
                best = Some((score, eta, accs));
            }
        }
        let (_, eta, accs) = best.unwrap();
        record(&format!("tsne(opt lr={eta})"), accs, t0.elapsed().as_secs_f64());

        // LINE at 2 dimensions (first-order) — the "embedding is not
        // visualization" baseline.
        let t0 = std::time::Instant::now();
        let edges: Vec<(u32, u32, f32)> =
            w.graph.edges().iter().filter(|&&(a, b, _)| a < b).map(|&(a, b, w)| (a, b, w as f32)).collect();
        let y = train_line(
            n,
            &edges,
            &LineConfig { dim: 2, samples_per_vertex: 2000, ..Default::default() },
        )
        .embedding;
        record("line-2d", eval(&y), t0.elapsed().as_secs_f64());

        // LargeVis with its single default config (paper regime:
        // T ≈ 10K samples per vertex; we use 6K to stay fast while
        // remaining in the saturated region of Fig 7b).
        let t0 = std::time::Instant::now();
        let y = layout(&w.graph, &LargeVisConfig { samples_per_vertex: 6000, ..Default::default() });
        record("largevis(default)", eval(&y), t0.elapsed().as_secs_f64());
    }
    table.print();
    table.write_tsv("fig5_vis_quality")?;
    Ok(())
}
