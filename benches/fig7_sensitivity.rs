//! Fig 7: LargeVis sensitivity to (a) the number of negative samples M
//! and (b) the number of training samples T, on wikidoc-like.
//!
//! Paper shape: accuracy saturates around M≈5 and is flat beyond; the
//! accuracy-vs-T curve saturates once T is a few thousand per vertex.

use largevis::bench::{bench_scale, workloads, Table};
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let w = workloads::prepare("wikidoc-like", 0.0125 * scale, 30, 0xf167);
    let labels = w.dataset.labels.as_ref().unwrap();
    eprintln!("[fig7] n={}", w.graph.n());
    let ecfg = KnnEvalConfig { k: 5, sample: 3000, ..Default::default() };

    let mut table = Table::new(
        "Fig 7a — sensitivity to negative samples M (T=2000/vertex)",
        &["M", "accuracy", "secs"],
    );
    for m in [1usize, 2, 3, 5, 7, 10] {
        let cfg = LargeVisConfig { negatives: m, samples_per_vertex: 2000, ..Default::default() };
        let t0 = std::time::Instant::now();
        let y = layout(&w.graph, &cfg);
        table.row(&[
            m.to_string(),
            format!("{:.4}", knn_accuracy(&y, labels, &ecfg)),
            format!("{:.2}", t0.elapsed().as_secs_f64()),
        ]);
    }
    table.print();
    table.write_tsv("fig7a_negatives")?;

    let mut table = Table::new(
        "Fig 7b — sensitivity to training samples per vertex (M=5)",
        &["samples/vertex", "accuracy", "secs"],
    );
    for t in [100usize, 400, 1000, 2000, 4000, 8000] {
        let cfg = LargeVisConfig { samples_per_vertex: t, ..Default::default() };
        let t0 = std::time::Instant::now();
        let y = layout(&w.graph, &cfg);
        table.row(&[
            t.to_string(),
            format!("{:.4}", knn_accuracy(&y, labels, &ecfg)),
            format!("{:.2}", t0.elapsed().as_secs_f64()),
        ]);
    }
    table.print();
    table.write_tsv("fig7b_samples")?;
    Ok(())
}
