//! Fig 2: running time vs accuracy of KNN graph construction across
//! four datasets, comparing random projection forests (Annoy-style),
//! vantage-point trees (t-SNE's method), NN-Descent, and LargeVis
//! (small forest + neighbor exploring).
//!
//! Paper shape to reproduce: LargeVis reaches the highest recall at the
//! lowest time (lower-right in the paper's axes); vp-trees are worst;
//! plain RP-forests need many trees to match LargeVis's recall.

use largevis::bench::{bench_scale, Table};
use largevis::data::datasets;
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::knn::nndescent::{nn_descent, NnDescentConfig};
use largevis::knn::rptree::{rp_forest_knn, RpForestConfig};
use largevis::knn::sampled_recall;
use largevis::knn::vptree::{vp_tree_knn, VpTreeConfig};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let k = 30; // paper: 150; scaled down with the datasets
    // (dataset, base scale) — sizes chosen so the full bench runs in
    // minutes on one core (LARGEVIS_BENCH_SCALE raises them).
    let sets = [
        ("20ng-like", 0.35),
        ("mnist-like", 0.05),
        ("wikidoc-like", 0.015),
        ("livejournal-like", 0.0125),
    ];
    let mut table = Table::new(
        "Fig 2 — KNN graph construction: time vs recall (K=50)",
        &["dataset", "n", "method", "param", "secs", "recall"],
    );

    for (name, base) in sets {
        let ds = datasets::generate(name, base * scale, 0xf162).unwrap();
        let n = ds.points.n();
        eprintln!("[fig2] {name}: n={n}");
        let mut record = |method: &str, param: String, secs: f64, g: &largevis::knn::KnnGraph| {
            let recall = sampled_recall(&ds.points, g, 300, 7, 0);
            table.row(&[
                name.into(),
                n.to_string(),
                method.into(),
                param,
                format!("{secs:.2}"),
                format!("{recall:.4}"),
            ]);
        };

        // Random projection forest: more trees -> higher recall.
        for trees in [1usize, 4, 16, 32] {
            let cfg = RpForestConfig { n_trees: trees, ..Default::default() };
            let t0 = std::time::Instant::now();
            let g = rp_forest_knn(&ds.points, k, &cfg);
            record("rp-forest", format!("trees={trees}"), t0.elapsed().as_secs_f64(), &g);
        }
        // Vantage-point tree: visit budget -> recall (exact = unbounded).
        for visits in [50usize, 200, 1000, usize::MAX] {
            let cfg = VpTreeConfig { max_visits: visits, ..Default::default() };
            let t0 = std::time::Instant::now();
            let g = vp_tree_knn(&ds.points, k, &cfg);
            let p = if visits == usize::MAX { "exact".into() } else { format!("visits={visits}") };
            record("vp-tree", p, t0.elapsed().as_secs_f64(), &g);
        }
        // k-d tree (extension: related-work baseline; great at low d,
        // collapses at high d).
        for visits in [200usize, usize::MAX] {
            let cfg = largevis::knn::kdtree::KdTreeConfig { max_visits: visits, ..Default::default() };
            let t0 = std::time::Instant::now();
            let g = largevis::knn::kdtree::kd_tree_knn(&ds.points, k, &cfg);
            let p = if visits == usize::MAX { "exact".into() } else { format!("visits={visits}") };
            record("kd-tree", p, t0.elapsed().as_secs_f64(), &g);
        }
        // LSH (extension: hashing baseline).
        for tables in [4usize, 16] {
            let cfg = largevis::knn::lsh::LshConfig { n_tables: tables, ..Default::default() };
            let t0 = std::time::Instant::now();
            let g = largevis::knn::lsh::lsh_knn(&ds.points, k, &cfg);
            record("lsh", format!("tables={tables}"), t0.elapsed().as_secs_f64(), &g);
        }
        // NN-Descent.
        for iters in [1usize, 3, 6] {
            let cfg =
                NnDescentConfig { max_iters: iters, sample_rate: 0.6, ..Default::default() };
            let t0 = std::time::Instant::now();
            let g = nn_descent(&ds.points, k, &cfg);
            record("nn-descent", format!("iters={iters}"), t0.elapsed().as_secs_f64(), &g);
        }
        // LargeVis: small forest + exploring.
        for (trees, iters) in [(2usize, 1usize), (4, 1), (8, 1)] {
            let cfg = LargeVisKnnConfig {
                forest: RpForestConfig { n_trees: trees, ..Default::default() },
                iters,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let g = largevis_knn(&ds.points, k, &cfg);
            record(
                "largevis",
                format!("trees={trees},explore={iters}"),
                t0.elapsed().as_secs_f64(),
                &g,
            );
        }
    }
    table.print();
    table.write_tsv("fig2_knn_construction")?;
    Ok(())
}
