//! Table 2: graph-visualization running time, BH t-SNE vs LargeVis,
//! across all seven datasets, with the speedup row.
//!
//! Paper shape: comparable on the small sets (20NG, MNIST), LargeVis
//! several times faster on the large ones (speedup grows with N —
//! O(N) sampling vs O(N log N) per full-batch iteration).

use largevis::baselines::{bh_tsne, BhTsneConfig};
use largevis::bench::{bench_scale, workloads, Table};
use largevis::util::timer::fmt_duration;
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    // All seven paper datasets, scaled so the full table runs in minutes.
    let sets = [
        ("20ng-like", 0.2),
        ("mnist-like", 0.04),
        ("wikiword-like", 0.02),
        ("wikidoc-like", 0.0125),
        ("livejournal-like", 0.01),
        ("csauthor-like", 0.02),
        ("dblp-like", 0.025),
    ];
    // Work-matched budgets mirroring the paper's settings (t-SNE: 1000
    // full-batch iterations; LargeVis: T ∝ N edge samples). We shrink
    // both by the same factor to keep the bench fast.
    let tsne_iters = 250;
    let samples_per_vertex = 2500;

    let mut table = Table::new(
        "Table 2 — layout running time (seconds)",
        &["dataset", "n", "tsne_secs", "largevis_secs", "speedup"],
    );

    for (name, base) in sets {
        let w = workloads::prepare(name, base * scale, 50, 0x7ab2);
        let n = w.graph.n();
        eprintln!("[table2] {name}: n={n} (knn took {})", fmt_duration(w.knn_secs));

        let t0 = std::time::Instant::now();
        let yt = bh_tsne(&w.graph, &BhTsneConfig { iters: tsne_iters, ..Default::default() });
        let tsne_secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(&yt);

        let t0 = std::time::Instant::now();
        let yl = layout(&w.graph, &LargeVisConfig { samples_per_vertex, ..Default::default() });
        let lv_secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(&yl);

        table.row(&[
            name.into(),
            n.to_string(),
            format!("{tsne_secs:.2}"),
            format!("{lv_secs:.2}"),
            format!("{:.1}", tsne_secs / lv_secs.max(1e-9)),
        ]);
    }
    table.print();
    table.write_tsv("table2_vis_runtime")?;
    Ok(())
}
