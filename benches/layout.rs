//! Layout-throughput benchmark: flat single-resolution SGD vs the
//! multilevel coarse-to-fine engine on the same weighted KNN graph.
//! Reports samples/sec, the exact LargeVis objective, and the
//! KNN-preservation score, and emits `BENCH_layout.json` so the
//! layout-stage perf trajectory starts recording (the multilevel entry
//! runs with **half** the fine-level sample budget, matching the
//! acceptance criterion). CI runs the smoke variant via
//! `LARGEVIS_BENCH_SCALE`.

use largevis::bench::{bench_scale, Table};
use largevis::data::synth::gaussian_mixture;
use largevis::eval::neighborhood_preservation;
use largevis::graph::weights::weighted_graph;
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::knn::rptree::RpForestConfig;
use largevis::vis::multilevel::{optimize_multilevel, MultilevelConfig};
use largevis::vis::objective::exact_objective;
use largevis::vis::{init_layout, sgd, LargeVisConfig};

const FLAT_SPV: usize = 400;

fn main() -> anyhow::Result<()> {
    let n = ((20_000.0 * bench_scale()) as usize).max(2_000);
    let (points, _) = gaussian_mixture(n, 16, 10, 0.4, 0xbe7c);
    let knn_cfg = LargeVisKnnConfig {
        forest: RpForestConfig { n_trees: 2, ..Default::default() },
        ..Default::default()
    };
    let knn = largevis_knn(&points, 10, &knn_cfg);
    let graph = weighted_graph(&knn, &Default::default());
    eprintln!("[layout] n={n} directed edges={}", graph.n_directed_edges());

    let base = LargeVisConfig { samples_per_vertex: FLAT_SPV, seed: 0x1a9, ..Default::default() };
    let mut table = Table::new("layout engines", &["mode", "metric", "value"]);
    let mut json_rows: Vec<String> = Vec::new();

    // Flat single-resolution SGD (the paper's engine).
    {
        let mut y = init_layout(graph.n(), base.dim, base.seed);
        let rep = sgd::optimize(&graph, &mut y, &base);
        let obj = exact_objective(&y, graph.edges(), base.gamma, base.prob_fn);
        let keep = neighborhood_preservation(&points, &y, 10, 300, 0xe5a1, 0);
        table.row(&["flat".into(), "samples/s".into(), format!("{:.0}", rep.throughput())]);
        table.row(&["flat".into(), "objective".into(), format!("{obj:.1}")]);
        table.row(&["flat".into(), "knn-preservation".into(), format!("{keep:.4}")]);
        json_rows.push(format!(
            concat!(
                "{{\"mode\":\"flat\",\"samples_per_vertex\":{},\"samples\":{},",
                "\"secs\":{:.4},\"samples_per_sec\":{:.0},\"objective\":{:.2},",
                "\"knn_preservation\":{:.4}}}"
            ),
            FLAT_SPV,
            rep.samples,
            rep.seconds,
            rep.throughput(),
            obj,
            keep
        ));
    }

    // Flat SGD pinned to one thread: together with the row above this
    // records the single- vs multi-thread throughput of the atomic
    // (relaxed per-f32) Hogwild loop, so any regression from the
    // AtomicU32 layout representation would show up here.
    {
        let cfg = LargeVisConfig { threads: 1, ..base.clone() };
        let mut y = init_layout(graph.n(), cfg.dim, cfg.seed);
        let rep = sgd::optimize(&graph, &mut y, &cfg);
        let obj = exact_objective(&y, graph.edges(), cfg.gamma, cfg.prob_fn);
        let tput = format!("{:.0}", rep.throughput());
        table.row(&["flat-1thread".into(), "samples/s".into(), tput]);
        table.row(&["flat-1thread".into(), "objective".into(), format!("{obj:.1}")]);
        json_rows.push(format!(
            concat!(
                "{{\"mode\":\"flat\",\"threads\":1,\"samples_per_vertex\":{},\"samples\":{},",
                "\"secs\":{:.4},\"samples_per_sec\":{:.0},\"objective\":{:.2}}}"
            ),
            FLAT_SPV,
            rep.samples,
            rep.seconds,
            rep.throughput(),
            obj
        ));
    }

    // Multilevel coarse-to-fine at half the fine-level budget.
    {
        let cfg = LargeVisConfig { samples_per_vertex: FLAT_SPV / 2, ..base.clone() };
        let ml = MultilevelConfig::default();
        let mut y = init_layout(graph.n(), cfg.dim, cfg.seed);
        let rep = optimize_multilevel(&graph, &mut y, &cfg, &ml, |_, _, _| Ok(()))?;
        let total = rep.total();
        let obj = exact_objective(&y, graph.edges(), cfg.gamma, cfg.prob_fn);
        let keep = neighborhood_preservation(&points, &y, 10, 300, 0xe5a1, 0);
        table.row(&[
            "multilevel".into(),
            "levels".into(),
            format!("{} (coarsest n={})", rep.levels.len(), rep.levels[0].n),
        ]);
        table.row(&["multilevel".into(), "samples/s".into(), format!("{:.0}", total.throughput())]);
        table.row(&["multilevel".into(), "objective".into(), format!("{obj:.1}")]);
        table.row(&["multilevel".into(), "knn-preservation".into(), format!("{keep:.4}")]);
        json_rows.push(format!(
            concat!(
                "{{\"mode\":\"multilevel\",\"samples_per_vertex\":{},\"fine_samples\":{},",
                "\"total_samples\":{},\"levels\":{},\"secs\":{:.4},\"samples_per_sec\":{:.0},",
                "\"objective\":{:.2},\"knn_preservation\":{:.4}}}"
            ),
            FLAT_SPV / 2,
            rep.fine().samples,
            total.samples,
            rep.levels.len(),
            total.seconds,
            total.throughput(),
            obj,
            keep
        ));
    }

    table.print();
    table.write_tsv("layout_engines")?;
    let doc = format!(
        "{{\"bench\":\"layout\",\"n\":{n},\"k\":10,\"results\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_layout.json", &doc)?;
    eprintln!("[layout] wrote BENCH_layout.json");
    Ok(())
}
