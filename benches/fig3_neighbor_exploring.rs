//! Fig 3: KNN-graph recall vs number of neighbor-exploring iterations,
//! starting from initial graphs of different accuracies (built with
//! different numbers of RP trees).
//!
//! Paper shape: recall jumps to ≈1 within 1–3 iterations even from a
//! very inaccurate start; curves starting higher converge faster.

use largevis::bench::{bench_scale, Table};
use largevis::data::datasets;
use largevis::knn::explore::{explore_once, LargeVisKnnConfig};
use largevis::knn::rptree::{rp_forest_knn, RpForestConfig};
use largevis::knn::sampled_recall;

fn main() -> anyhow::Result<()> {
    let scale = bench_scale();
    let k = 30;
    let sets = [("wikidoc-like", 0.015), ("livejournal-like", 0.0125)];
    let mut table = Table::new(
        "Fig 3 — recall vs neighbor-exploring iterations (K=50)",
        &["dataset", "init_trees", "iter", "recall", "cum_secs"],
    );

    for (name, base) in sets {
        let ds = datasets::generate(name, base * scale, 0xf163).unwrap();
        eprintln!("[fig3] {name}: n={}", ds.points.n());
        for trees in [1usize, 2, 4, 8] {
            let t0 = std::time::Instant::now();
            let mut g = rp_forest_knn(&ds.points, k, &RpForestConfig { n_trees: trees, ..Default::default() });
            let cfg = LargeVisKnnConfig::default();
            for iter in 0..=3usize {
                if iter > 0 {
                    g = explore_once(&ds.points, &g, &cfg);
                }
                let recall = sampled_recall(&ds.points, &g, 300, 11, 0);
                table.row(&[
                    name.into(),
                    trees.to_string(),
                    iter.to_string(),
                    format!("{recall:.4}"),
                    format!("{:.2}", t0.elapsed().as_secs_f64()),
                ]);
            }
        }
    }
    table.print();
    table.write_tsv("fig3_neighbor_exploring")?;
    Ok(())
}
