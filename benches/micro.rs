//! Micro-benchmarks of the hot paths (§Perf): alias sampling, distance
//! kernels, per-edge gradient step, Hogwild thread scaling, quadtree
//! build, RP-tree build, perplexity calibration, and the XLA batched
//! step latency (if artifacts exist).

use largevis::bench::{time_fn, Table};
use largevis::data::matrix::sqdist;
use largevis::data::synth::gaussian_mixture;
use largevis::graph::weights::calibrate_row;
use largevis::util::alias::AliasTable;
use largevis::util::rng::Rng;
use largevis::vis::{init_layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let mut table = Table::new("micro-benchmarks", &["bench", "metric", "value"]);

    // Alias sampling throughput.
    {
        let mut rng = Rng::new(1);
        let w: Vec<f64> = (0..1_000_000).map(|_| rng.f64() + 0.01).collect();
        let t = AliasTable::new(&w);
        let s = time_fn(1, 5, || {
            let mut acc = 0usize;
            for _ in 0..1_000_000 {
                acc ^= t.sample(&mut rng);
            }
            acc
        });
        table.row(&[
            "alias.sample".into(),
            "M samples/s".into(),
            format!("{:.0}", 1.0 / s.p50),
        ]);
    }

    // sqdist throughput at d=100 (the KNN hot scalar).
    {
        let mut rng = Rng::new(2);
        let a: Vec<f32> = (0..100).map(|_| rng.gaussian()).collect();
        let b: Vec<f32> = (0..100).map(|_| rng.gaussian()).collect();
        let s = time_fn(2, 5, || {
            let mut acc = 0f32;
            for _ in 0..1_000_000 {
                acc += sqdist(std::hint::black_box(&a), std::hint::black_box(&b));
            }
            acc
        });
        table.row(&[
            "sqdist(d=100)".into(),
            "M dists/s".into(),
            format!("{:.0}", 1.0 / s.p50),
        ]);
    }

    // Hogwild SGD throughput & thread scaling on an SBM graph.
    {
        let g = largevis::data::synth::sbm(20_000, 10, 12.0, 1.0, 3);
        let edges: Vec<(u32, u32, f64)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        let graph = largevis::graph::CsrGraph::from_undirected(g.n, &edges);
        for threads in [1usize, 2, 4, 8, 0] {
            let label = if threads == 0 {
                format!("auto({})", largevis::util::pool::default_threads())
            } else {
                threads.to_string()
            };
            let cfg = LargeVisConfig { samples_per_vertex: 500, threads, ..Default::default() };
            let mut y = init_layout(g.n, 2, 1);
            let rep = largevis::vis::sgd::optimize(&graph, &mut y, &cfg);
            table.row(&[
                format!("sgd.hogwild(threads={label})"),
                "M samples/s".into(),
                format!("{:.2}", rep.throughput() / 1e6),
            ]);
        }
    }

    // RP-tree forest build.
    {
        let (m, _) = gaussian_mixture(20_000, 100, 10, 0.3, 4);
        let s = time_fn(0, 3, || {
            largevis::knn::rptree::rp_forest_knn(
                &m,
                20,
                &largevis::knn::rptree::RpForestConfig::default(),
            )
        });
        table.row(&["rpforest.build(n=20k,d=100,8 trees)".into(), "secs".into(), format!("{:.3}", s.p50)]);
    }

    // Quadtree build.
    {
        let y = init_layout(100_000, 2, 5);
        let s = time_fn(1, 5, || largevis::baselines::QuadTree::build(&y));
        table.row(&["quadtree.build(n=100k)".into(), "ms".into(), format!("{:.2}", s.p50 * 1e3)]);
    }

    // Perplexity calibration per row.
    {
        let mut rng = Rng::new(6);
        let dists: Vec<f32> = (0..150).map(|_| rng.f32() * 10.0).collect();
        let s = time_fn(10, 5, || {
            let mut acc = 0f64;
            for _ in 0..1000 {
                acc += calibrate_row(std::hint::black_box(&dists), 50.0, 64, 1e-5)[0];
            }
            acc
        });
        table.row(&[
            "perplexity.calibrate(k=150)".into(),
            "K rows/s".into(),
            format!("{:.1}", 1.0 / s.p50),
        ]);
    }

    // XLA batched step latency (skipped without artifacts).
    match largevis::runtime::Runtime::from_default_dir() {
        Ok(rt) => {
            let mf = rt.manifest;
            let (b, m, s_dim) = (mf.batch, mf.negatives, mf.dim);
            let mut rng = Rng::new(7);
            let yi: Vec<f32> = (0..b * s_dim).map(|_| rng.gaussian()).collect();
            let yj: Vec<f32> = (0..b * s_dim).map(|_| rng.gaussian()).collect();
            let yn: Vec<f32> = (0..b * m * s_dim).map(|_| rng.gaussian()).collect();
            let s = time_fn(3, 10, || {
                rt.run(
                    "grad_kernel",
                    &[
                        largevis::runtime::literal_f32_2d(&yi, b, s_dim).unwrap(),
                        largevis::runtime::literal_f32_2d(&yj, b, s_dim).unwrap(),
                        largevis::runtime::literal_f32_2d(&yn, b, m * s_dim).unwrap(),
                        largevis::runtime::literal_f32(7.0),
                    ],
                )
                .unwrap()
            });
            table.row(&[
                format!("xla.grad_kernel(B={b})"),
                "µs/batch".into(),
                format!("{:.0}", s.p50 * 1e6),
            ]);
            table.row(&[
                "xla.grad_kernel".into(),
                "M samples/s".into(),
                format!("{:.2}", b as f64 / s.p50 / 1e6),
            ]);
        }
        Err(e) => eprintln!("[micro] xla bench skipped: {e}"),
    }

    table.print();
    table.write_tsv("micro")?;
    Ok(())
}
