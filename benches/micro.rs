//! Micro-benchmarks of the hot paths (§Perf): alias sampling, distance
//! kernels, per-edge gradient step, Hogwild thread scaling, quadtree
//! build, RP-tree build, perplexity calibration, and the XLA batched
//! step latency (if artifacts exist).

use largevis::bench::{time_fn, Table};
use largevis::data::matrix::sqdist;
use largevis::data::synth::gaussian_mixture;
use largevis::graph::weights::calibrate_row;
use largevis::util::alias::AliasTable;
use largevis::util::rng::Rng;
use largevis::vis::{init_layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let mut table = Table::new("micro-benchmarks", &["bench", "metric", "value"]);

    // Alias sampling throughput.
    {
        let mut rng = Rng::new(1);
        let w: Vec<f64> = (0..1_000_000).map(|_| rng.f64() + 0.01).collect();
        let t = AliasTable::new(&w);
        let s = time_fn(1, 5, || {
            let mut acc = 0usize;
            for _ in 0..1_000_000 {
                acc ^= t.sample(&mut rng);
            }
            acc
        });
        table.row(&[
            "alias.sample".into(),
            "M samples/s".into(),
            format!("{:.0}", 1.0 / s.p50),
        ]);
    }

    // sqdist throughput at d=100 (the KNN hot scalar; dispatched path).
    {
        let mut rng = Rng::new(2);
        let a: Vec<f32> = (0..100).map(|_| rng.gaussian()).collect();
        let b: Vec<f32> = (0..100).map(|_| rng.gaussian()).collect();
        let s = time_fn(2, 5, || {
            let mut acc = 0f32;
            for _ in 0..1_000_000 {
                acc += sqdist(std::hint::black_box(&a), std::hint::black_box(&b));
            }
            acc
        });
        table.row(&[
            "sqdist(d=100)".into(),
            "M dists/s".into(),
            format!("{:.0}", 1.0 / s.p50),
        ]);
    }

    // Distance-kernel comparison: scalar reference vs the dispatched
    // SIMD variant vs the batched gather kernel, across the paper's
    // dimensionality range (d=784 is MNIST). Emits BENCH_kernels.json
    // so the perf trajectory is recorded from this PR onward.
    {
        let active = largevis::kernels::active();
        let mut json_rows: Vec<String> = Vec::new();
        for d in [10usize, 50, 100, 200, 784] {
            let mut rng = Rng::new(0xd15 + d as u64);
            let a: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
            // Constant-work iteration counts so every d times in ~the
            // same ballpark.
            let iters = (20_000_000 / d).max(20_000);
            let time_pair = |f: fn(&[f32], &[f32]) -> f32| {
                time_fn(1, 5, || {
                    let mut acc = 0f32;
                    for _ in 0..iters {
                        acc += f(std::hint::black_box(&a), std::hint::black_box(&b));
                    }
                    acc
                })
            };
            let scalar_s = time_pair(largevis::kernels::SCALAR.sqdist);
            let simd_s = time_pair(active.sqdist);

            // Batched: one query against 256 candidate rows scattered
            // through a larger matrix — shuffled ids so the gather cost
            // matches the real KNN access pattern (leaf/bucket ids are
            // not sequential), not a prefetchable sequential copy.
            let rows = 256usize;
            let pool_rows = rows * 8;
            let m = largevis::data::Matrix::from_vec(
                (0..pool_rows * d).map(|_| rng.gaussian()).collect(),
                pool_rows,
                d,
            );
            let mut ids: Vec<u32> = (0..pool_rows as u32).collect();
            rng.shuffle(&mut ids);
            ids.truncate(rows);
            let reps = (iters / rows).max(16);
            let mut out: Vec<f32> = Vec::new();
            let batch_s = time_fn(1, 5, || {
                let mut acc = 0f32;
                for _ in 0..reps {
                    largevis::kernels::sqdist_batch(
                        std::hint::black_box(&a),
                        &m,
                        std::hint::black_box(&ids),
                        &mut out,
                    );
                    acc += out[0] + out[rows - 1];
                }
                acc
            });

            let scalar_ns = scalar_s.p50 / iters as f64 * 1e9;
            let simd_ns = simd_s.p50 / iters as f64 * 1e9;
            let batch_ns = batch_s.p50 / (reps * rows) as f64 * 1e9;
            let simd_speedup = scalar_ns / simd_ns;
            let batch_speedup = scalar_ns / batch_ns;
            table.row(&[
                format!("kernels.sqdist(d={d})"),
                format!("ns scalar/{}/batch", active.name),
                format!("{scalar_ns:.1}/{simd_ns:.1}/{batch_ns:.1}"),
            ]);
            table.row(&[
                format!("kernels.speedup(d={d})"),
                format!("{}x/batchx vs scalar", active.name),
                format!("{simd_speedup:.2}/{batch_speedup:.2}"),
            ]);
            json_rows.push(format!(
                concat!(
                    "{{\"d\":{},\"scalar_ns\":{:.2},\"simd_ns\":{:.2},\"batch_ns\":{:.2},",
                    "\"simd_speedup\":{:.3},\"batch_speedup\":{:.3}}}"
                ),
                d, scalar_ns, simd_ns, batch_ns, simd_speedup, batch_speedup
            ));
        }
        let doc = format!(
            "{{\"bench\":\"kernels.sqdist\",\"active_kernel\":\"{}\",\"results\":[{}]}}\n",
            active.name,
            json_rows.join(",")
        );
        std::fs::write("BENCH_kernels.json", &doc)?;
        eprintln!("[micro] wrote BENCH_kernels.json (active kernel: {})", active.name);
    }

    // Hogwild SGD throughput & thread scaling on an SBM graph.
    {
        let g = largevis::data::synth::sbm(20_000, 10, 12.0, 1.0, 3);
        let edges: Vec<(u32, u32, f64)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        let graph = largevis::graph::CsrGraph::from_undirected(g.n, &edges);
        for threads in [1usize, 2, 4, 8, 0] {
            let label = if threads == 0 {
                format!("auto({})", largevis::util::pool::default_threads())
            } else {
                threads.to_string()
            };
            let cfg = LargeVisConfig { samples_per_vertex: 500, threads, ..Default::default() };
            let mut y = init_layout(g.n, 2, 1);
            let rep = largevis::vis::sgd::optimize(&graph, &mut y, &cfg);
            table.row(&[
                format!("sgd.hogwild(threads={label})"),
                "M samples/s".into(),
                format!("{:.2}", rep.throughput() / 1e6),
            ]);
        }
    }

    // RP-tree forest build.
    {
        let (m, _) = gaussian_mixture(20_000, 100, 10, 0.3, 4);
        let s = time_fn(0, 3, || {
            largevis::knn::rptree::rp_forest_knn(
                &m,
                20,
                &largevis::knn::rptree::RpForestConfig::default(),
            )
        });
        table.row(&["rpforest.build(n=20k,d=100,8 trees)".into(), "secs".into(), format!("{:.3}", s.p50)]);
    }

    // Quadtree build.
    {
        let y = init_layout(100_000, 2, 5);
        let s = time_fn(1, 5, || largevis::baselines::QuadTree::build(&y));
        table.row(&["quadtree.build(n=100k)".into(), "ms".into(), format!("{:.2}", s.p50 * 1e3)]);
    }

    // Perplexity calibration per row.
    {
        let mut rng = Rng::new(6);
        let dists: Vec<f32> = (0..150).map(|_| rng.f32() * 10.0).collect();
        let s = time_fn(10, 5, || {
            let mut acc = 0f64;
            for _ in 0..1000 {
                acc += calibrate_row(std::hint::black_box(&dists), 50.0, 64, 1e-5)[0];
            }
            acc
        });
        table.row(&[
            "perplexity.calibrate(k=150)".into(),
            "K rows/s".into(),
            format!("{:.1}", 1.0 / s.p50),
        ]);
    }

    // XLA batched step latency (skipped without artifacts).
    match largevis::runtime::Runtime::from_default_dir() {
        Ok(rt) => {
            let mf = rt.manifest;
            let (b, m, s_dim) = (mf.batch, mf.negatives, mf.dim);
            let mut rng = Rng::new(7);
            let yi: Vec<f32> = (0..b * s_dim).map(|_| rng.gaussian()).collect();
            let yj: Vec<f32> = (0..b * s_dim).map(|_| rng.gaussian()).collect();
            let yn: Vec<f32> = (0..b * m * s_dim).map(|_| rng.gaussian()).collect();
            let s = time_fn(3, 10, || {
                rt.run(
                    "grad_kernel",
                    &[
                        largevis::runtime::literal_f32_2d(&yi, b, s_dim).unwrap(),
                        largevis::runtime::literal_f32_2d(&yj, b, s_dim).unwrap(),
                        largevis::runtime::literal_f32_2d(&yn, b, m * s_dim).unwrap(),
                        largevis::runtime::literal_f32(7.0),
                    ],
                )
                .unwrap()
            });
            table.row(&[
                format!("xla.grad_kernel(B={b})"),
                "µs/batch".into(),
                format!("{:.0}", s.p50 * 1e6),
            ]);
            table.row(&[
                "xla.grad_kernel".into(),
                "M samples/s".into(),
                format!("{:.2}", b as f64 / s.p50 / 1e6),
            ]);
        }
        Err(e) => eprintln!("[micro] xla bench skipped: {e}"),
    }

    table.print();
    table.write_tsv("micro")?;
    Ok(())
}
