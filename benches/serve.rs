//! Serve-throughput smoke benchmark: insert/sec through the live
//! write path and query/sec through `/knn` — with keep-alive
//! connections vs one-connection-per-request (`Connection: close`) —
//! against an in-process server on an ephemeral port. Emits
//! `BENCH_serve.json` so the serving-perf trajectory starts recording;
//! CI runs the smoke variant via `LARGEVIS_BENCH_SCALE`.

use largevis::bench::{bench_scale, Table};
use largevis::config::{PipelineConfig, SearchMode, ServeConfig};
use largevis::coordinator::CheckpointPaths;
use largevis::data::chunked::copied_bytes;
use largevis::serve::{Server, ServerState};
use largevis::util::timer::Timer;
use std::net::SocketAddr;

#[path = "../rust/tests/util/mod.rs"]
mod util;
use util::{json_row, request, KeepAlive};

/// One request on a fresh connection (`Connection: close`).
fn request_close(addr: SocketAddr, method: &str, path: &str, body: &str) -> u16 {
    request(addr, method, path, Some(body)).0
}

/// Fabricated checkpoint directory for the publish-scaling rows: `n`
/// collinear 4-d points with a degree-4 ring KNN (the same shape the
/// `publish_cost` test uses, so bench and regression test measure the
/// same path).
fn fabricate_base(dir: &std::path::Path, n: usize) -> anyhow::Result<()> {
    use largevis::data::formats::{binary, checkpoint};
    use largevis::data::matrix::Matrix;
    use largevis::knn::KnnGraph;
    std::fs::create_dir_all(dir)?;
    let paths = CheckpointPaths::in_dir(dir);
    let data: Vec<f32> = (0..n).flat_map(|i| [i as f32 * 0.25; 4]).collect();
    let data = Matrix::from_vec(data, n, 4);
    let layout: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.5).collect();
    binary::write_binary(&paths.data, &data)?;
    binary::write_binary(&paths.layout, &Matrix::from_vec(layout, n, 2))?;
    let mut knn = KnnGraph::empty(n, 4);
    for i in 0..n {
        let mut row: Vec<(u32, f32)> = [n - 2, n - 1, 1, 2]
            .iter()
            .map(|&off| {
                let j = (i + off) % n;
                let dd: f32 =
                    data.row(i).iter().zip(data.row(j)).map(|(a, b)| (a - b) * (a - b)).sum();
                (j as u32, dd)
            })
            .collect();
        row.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        knn.neighbors[i] = row;
    }
    checkpoint::write_knn(&paths.knn, &knn)?;
    std::fs::write(&paths.meta, "publish-bench")?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // A small checkpointed pipeline run to serve.
    let out_dir = std::env::temp_dir().join(format!("largevis_serve_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&out_dir).ok();
    let mut cfg = PipelineConfig {
        dataset: "20ng-like".into(),
        scale: (0.05 * bench_scale()).clamp(0.01, 1.0),
        k: 10,
        out_dir: out_dir.clone(),
        ..Default::default()
    };
    cfg.vis.samples_per_vertex = 300;
    cfg.knn.forest.n_trees = 2;
    largevis::coordinator::run_pipeline(&cfg)?;
    let ckpt = CheckpointPaths::new(&out_dir);

    let mut table = Table::new("serve throughput", &["workload", "metric", "value"]);
    let mut json_rows: Vec<String> = Vec::new();

    // --- exact vs graph query path: in-process latency + recall ---
    // Both states load the same checkpoints (no WAL yet, so the loads
    // are cheap and identical); `query_knn` is the exact dispatch the
    // `/knn` handler uses, minus HTTP framing, so the ratio isolates
    // the search algorithms.
    {
        let mk = |search: SearchMode| ServeConfig {
            checkpoints: ckpt.dir.clone(),
            addr: "127.0.0.1:0".to_string(),
            search,
            ..Default::default()
        };
        let q_n = ((200.0 * bench_scale()) as usize).max(40);

        let exact = ServerState::load(mk(SearchMode::Exact))?;
        let qsnap = exact.snapshot();
        let qn = qsnap.data.n();
        let k = 10.min(qn);
        let t = Timer::start("knn-exact-inproc");
        let oracle: Vec<Vec<(u32, f32)>> =
            (0..q_n).map(|i| exact.query_knn(&qsnap, qsnap.data.row(i % qn), k)).collect();
        let secs = t.report();
        let qps = q_n as f64 / secs.max(1e-9);
        table.row(&["knn/exact in-proc".into(), "req/s".into(), format!("{qps:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"knn_exact_inproc\",\"requests\":{q_n},\"secs\":{secs:.4},\"per_sec\":{qps:.1}}}"
        ));
        drop(qsnap);
        drop(exact);

        let graph = ServerState::load(mk(SearchMode::Graph))?;
        let qsnap = graph.snapshot();
        let t = Timer::start("knn-graph-inproc");
        let got: Vec<Vec<(u32, f32)>> =
            (0..q_n).map(|i| graph.query_knn(&qsnap, qsnap.data.row(i % qn), k)).collect();
        let secs = t.report();
        let qps = q_n as f64 / secs.max(1e-9);
        let mut hit = 0usize;
        for (o, g) in oracle.iter().zip(&got) {
            let truth: std::collections::HashSet<u32> = o.iter().map(|&(id, _)| id).collect();
            hit += g.iter().filter(|&&(id, _)| truth.contains(&id)).count();
        }
        let recall = hit as f64 / (q_n * k) as f64;
        let scored = {
            let m = graph.metrics.lock().unwrap_or_else(|e| e.into_inner());
            m.get("serve.search_scored").unwrap_or(0.0)
        } / q_n as f64;
        table.row(&["knn/graph in-proc".into(), "req/s".into(), format!("{qps:.0}")]);
        table.row(&["knn/graph".into(), format!("recall@{k}"), format!("{recall:.4}")]);
        table.row(&["knn/graph".into(), "scored/query".into(), format!("{scored:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"knn_graph_inproc\",\"requests\":{q_n},\"secs\":{secs:.4},\"per_sec\":{qps:.1},\"recall_at_{k}\":{recall:.4},\"mean_scored\":{scored:.1}}}"
        ));
        eprintln!(
            "[serve-bench] graph vs exact: recall@{k}={recall:.4}, scored/query={scored:.0}/{qn}"
        );
        drop(qsnap);
        drop(graph);
    }

    let serve_cfg = ServeConfig {
        checkpoints: ckpt.dir.clone(),
        addr: "127.0.0.1:0".to_string(),
        threads: 4,
        insert_samples: 100,
        refine_interval_ms: 100,
        ..Default::default()
    };
    let state = ServerState::load(serve_cfg)?;
    let server = Server::bind(state)?;
    let addr = server.local_addr()?;
    let shared = server.state();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());

    let snap = shared.snapshot();
    let n = snap.data.n();
    let d = snap.data.d();
    let queries = ((400.0 * bench_scale()) as usize).max(50);
    let inserts = ((200.0 * bench_scale()) as usize).max(20);
    eprintln!("[serve-bench] n={n} d={d} queries={queries} inserts={inserts} addr={addr}");

    let knn_body = format!("{{\"point\":{},\"k\":5}}", json_row(snap.data.row(0)));

    // Query throughput, one connection per request (graph search mode,
    // the serving default — the in-proc rows above carry the exact
    // baseline).
    {
        let t = Timer::start("knn-close");
        for _ in 0..queries {
            assert_eq!(request_close(addr, "POST", "/knn", &knn_body), 200);
        }
        let secs = t.report();
        let qps = queries as f64 / secs.max(1e-9);
        table.row(&["knn/close".into(), "req/s".into(), format!("{qps:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"knn_close\",\"requests\":{queries},\"secs\":{secs:.4},\"per_sec\":{qps:.1}}}"
        ));
    }

    // Query throughput, one persistent keep-alive connection.
    {
        let mut conn = KeepAlive::connect(addr);
        let t = Timer::start("knn-keepalive");
        for _ in 0..queries {
            assert_eq!(conn.request("POST", "/knn", &knn_body), 200);
        }
        let secs = t.report();
        let qps = queries as f64 / secs.max(1e-9);
        table.row(&["knn/keep-alive".into(), "req/s".into(), format!("{qps:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"knn_keepalive\",\"requests\":{queries},\"secs\":{secs:.4},\"per_sec\":{qps:.1}}}"
        ));
    }

    // Readiness-probe throughput (the endpoint load balancers poll;
    // keep-alive, no body, no snapshot work).
    {
        let mut conn = KeepAlive::connect(addr);
        let t = Timer::start("readyz");
        for _ in 0..queries {
            assert_eq!(conn.request("GET", "/readyz", ""), 200);
        }
        let secs = t.report();
        let qps = queries as f64 / secs.max(1e-9);
        table.row(&["readyz/keep-alive".into(), "req/s".into(), format!("{qps:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"readyz\",\"requests\":{queries},\"secs\":{secs:.4},\"per_sec\":{qps:.1}}}"
        ));
    }

    // Insert throughput (single-point inserts over keep-alive; each
    // request WALs, splices, places and publishes an epoch).
    {
        let mut conn = KeepAlive::connect(addr);
        let t = Timer::start("insert");
        for i in 0..inserts {
            let vals: Vec<f32> = snap
                .data
                .row(i % n)
                .iter()
                .map(|v| v + 0.01 * (i + 1) as f32)
                .collect();
            let body = format!("{{\"point\":{}}}", json_row(&vals));
            assert_eq!(conn.request("POST", "/insert", &body), 200);
        }
        let secs = t.report();
        let ips = inserts as f64 / secs.max(1e-9);
        table.row(&["insert".into(), "req/s".into(), format!("{ips:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"insert\",\"requests\":{inserts},\"secs\":{secs:.4},\"per_sec\":{ips:.1}}}"
        ));
    }

    // Batched insert throughput (rows/sec, amortizing the epoch swap).
    {
        let batch = 32usize;
        let batches = (inserts / 8).max(3);
        let mut conn = KeepAlive::connect(addr);
        let t = Timer::start("insert-batch");
        for b in 0..batches {
            let rows: Vec<String> = (0..batch)
                .map(|r| {
                    let vals: Vec<f32> = snap
                        .data
                        .row((b * batch + r) % n)
                        .iter()
                        .map(|v| v + 0.02 * (r + 1) as f32)
                        .collect();
                    json_row(&vals)
                })
                .collect();
            let body = format!("{{\"points\":[{}]}}", rows.join(","));
            assert_eq!(conn.request("POST", "/insert_batch", &body), 200);
        }
        let secs = t.report();
        let rps = (batches * batch) as f64 / secs.max(1e-9);
        table.row(&["insert_batch".into(), "rows/s".into(), format!("{rps:.0}")]);
        json_rows.push(format!(
            "{{\"workload\":\"insert_batch\",\"rows\":{},\"secs\":{secs:.4},\"per_sec\":{rps:.1}}}",
            batches * batch
        ));
    }

    handle.shutdown();
    server_thread.join().expect("server thread")?;

    // Publish scaling: insert rows/sec and per-publish latency +
    // copied bytes at three chunk-aligned base sizes (in-process, no
    // HTTP framing). The chunked copy-on-write snapshot store makes a
    // publish O(batch); these three rows catch any super-constant
    // degradation with the base size.
    for &full_base in &[4096usize, 16_384, 65_536] {
        let chunks =
            ((full_base as f64 * bench_scale() / 1024.0).round() as usize).max(1);
        let base_n = chunks * 1024;
        let dir = std::env::temp_dir()
            .join(format!("largevis_serve_bench_pub_{}_{base_n}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        fabricate_base(&dir, base_n)?;
        let st = ServerState::load(ServeConfig {
            checkpoints: dir.clone(),
            search: SearchMode::Exact,
            insert_samples: 8,
            refine_samples: 0,
            ..Default::default()
        })?;
        let (batch_rows, batches) = (8usize, 12usize);
        let bytes0 = copied_bytes();
        let t = Timer::start("insert-batch-publish");
        for b in 0..batches {
            let mut vals = Vec::with_capacity(batch_rows * 4);
            for r in 0..batch_rows {
                let near = (100 + 40 * r + 3 * b) as f32;
                vals.extend_from_slice(&[near * 0.25 + 0.1; 4]);
            }
            st.insert(&largevis::data::matrix::Matrix::from_vec(vals, batch_rows, 4))?;
        }
        let secs = t.report();
        let rows = batch_rows * batches;
        let rps = rows as f64 / secs.max(1e-9);
        let publish_us = secs * 1e6 / batches as f64;
        let copied_per_publish = (copied_bytes() - bytes0) / batches as u64;
        table.row(&[
            format!("insert_batch/base={base_n}"),
            "rows/s".into(),
            format!("{rps:.0}"),
        ]);
        table.row(&[
            format!("insert_batch/base={base_n}"),
            "us/publish".into(),
            format!("{publish_us:.0}"),
        ]);
        table.row(&[
            format!("insert_batch/base={base_n}"),
            "copied B/publish".into(),
            format!("{copied_per_publish}"),
        ]);
        json_rows.push(format!(
            "{{\"workload\":\"insert_batch_publish\",\"base_rows\":{base_n},\"rows\":{rows},\
             \"secs\":{secs:.4},\"per_sec\":{rps:.1},\"publish_us\":{publish_us:.1},\
             \"copied_bytes_per_publish\":{copied_per_publish}}}"
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    table.print();
    table.write_tsv("serve_throughput")?;
    let doc = format!(
        "{{\"bench\":\"serve\",\"n\":{n},\"d\":{d},\"results\":[{}]}}\n",
        json_rows.join(",")
    );
    std::fs::write("BENCH_serve.json", &doc)?;
    eprintln!("[serve-bench] wrote BENCH_serve.json");
    Ok(())
}
