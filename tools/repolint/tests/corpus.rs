//! Fixture corpus for repolint: known-bad snippets with exact expected
//! per-rule violation counts, plus false-positive traps that must stay
//! at zero findings.

use repolint::{
    lex, parse_allow, scan_source, Options, Violation, RULE_NO_PANIC, RULE_ORDERING_JUSTIFIED,
    RULE_REPLAY_DETERMINISM, RULE_SYNC_SHIM, RULE_UNSAFE_SAFETY,
};

fn count(vs: &[Violation], rule: &str) -> usize {
    vs.iter().filter(|v| v.rule == rule && !v.allowed).count()
}

#[test]
fn unannotated_unsafe_counts() {
    let src = include_str!("fixtures/unsafe_unannotated.rs");
    let vs = scan_source("kernels/fixture.rs", src, &Options::repo_defaults());
    assert_eq!(count(&vs, RULE_UNSAFE_SAFETY), 3, "{vs:?}");
    assert_eq!(count(&vs, RULE_NO_PANIC), 0, "{vs:?}");
    assert_eq!(count(&vs, RULE_ORDERING_JUSTIFIED), 0, "{vs:?}");
    assert_eq!(count(&vs, RULE_REPLAY_DETERMINISM), 0, "{vs:?}");
}

#[test]
fn test_gated_vs_live_unwraps() {
    let src = include_str!("fixtures/unwrap_scopes.rs");
    let vs = scan_source("serve/fixture.rs", src, &Options::repo_defaults());
    // unwrap + expect + panic! + cfg(not(test)) unwrap + cfg(any(test,
    // unix)) todo! are live; everything under cfg(test)/cfg(all(test,
    // ..)) is exempt, and unwrap_or/unwrap_or_else never count.
    assert_eq!(count(&vs, RULE_NO_PANIC), 5, "{vs:?}");
    let lines: Vec<usize> =
        vs.iter().filter(|v| v.rule == RULE_NO_PANIC).map(|v| v.line).collect();
    assert_eq!(lines, vec![4, 5, 7, 19, 24], "{vs:?}");
}

#[test]
fn out_of_scope_path_skips_panic_rule() {
    let src = include_str!("fixtures/unwrap_scopes.rs");
    let vs = scan_source("vis/fixture.rs", src, &Options::repo_defaults());
    assert_eq!(count(&vs, RULE_NO_PANIC), 0, "{vs:?}");
}

#[test]
fn string_and_comment_traps_stay_clean() {
    let src = include_str!("fixtures/traps.rs");
    let vs = scan_source("serve/traps.rs", src, &Options::repo_defaults());
    assert!(vs.is_empty(), "false positives: {vs:?}");
}

#[test]
fn ordering_and_replay_counts() {
    let src = include_str!("fixtures/ordering_and_replay.rs");
    let vs = scan_source("data/formats/wal.rs", src, &Options::repo_defaults());
    // Every explicit Ordering:: (Relaxed/SeqCst/Acquire/Release/AcqRel)
    // needs a justification; annotated uses (same line or contiguous
    // comment above) are compliant.
    assert_eq!(count(&vs, RULE_ORDERING_JUSTIFIED), 4, "{vs:?}");
    assert_eq!(count(&vs, RULE_REPLAY_DETERMINISM), 2, "{vs:?}");
    // The std::sync import itself trips the sync-shim rule here.
    assert_eq!(count(&vs, RULE_SYNC_SHIM), 1, "{vs:?}");
}

#[test]
fn replay_rule_is_scoped() {
    let src = include_str!("fixtures/ordering_and_replay.rs");
    let vs = scan_source("serve/state.rs", src, &Options::repo_defaults());
    assert_eq!(count(&vs, RULE_REPLAY_DETERMINISM), 0, "{vs:?}");
    // The ordering rule is repo-wide, so those findings remain.
    assert_eq!(count(&vs, RULE_ORDERING_JUSTIFIED), 4, "{vs:?}");
}

#[test]
fn ordering_rule_exempts_the_sync_shim() {
    let src = include_str!("fixtures/ordering_and_replay.rs");
    let vs = scan_source("util/sync/shim.rs", src, &Options::repo_defaults());
    // The shim interprets caller-passed orderings; per-site
    // justifications are waived there (and only there).
    assert_eq!(count(&vs, RULE_ORDERING_JUSTIFIED), 0, "{vs:?}");
}

#[test]
fn sync_shim_rule_counts_and_scoping() {
    let src = include_str!("fixtures/sync_shim.rs");
    let vs = scan_source("serve/fixture.rs", src, &Options::repo_defaults());
    // Two imports + one fully-qualified use; the crate::util::sync
    // import, comments, strings, and cfg(test) code stay clean.
    assert_eq!(count(&vs, RULE_SYNC_SHIM), 3, "{vs:?}");
    let vs = scan_source("vis/fixture.rs", src, &Options::repo_defaults());
    assert_eq!(count(&vs, RULE_SYNC_SHIM), 0, "{vs:?}");
}

#[test]
fn allow_list_downgrades_matching_violations() {
    let src = include_str!("fixtures/unwrap_scopes.rs");
    let mut opts = Options::repo_defaults();
    opts.allow = parse_allow(
        "# comment lines and blanks are ignored\n\n\
         no-panic serve/fixture.rs panic!(\"too big\")\n",
    );
    let vs = scan_source("serve/fixture.rs", src, &opts);
    assert_eq!(count(&vs, RULE_NO_PANIC), 4, "{vs:?}");
    assert_eq!(vs.iter().filter(|v| v.allowed).count(), 1, "{vs:?}");
}

#[test]
fn lexer_splits_code_and_comments() {
    let lines = lex("let x = 1; // trailing note\n\"str // not comment\";\n");
    assert_eq!(lines.len(), 2);
    assert_eq!(lines[0].code.trim(), "let x = 1;");
    assert!(lines[0].comment.contains("trailing note"));
    assert!(!lines[1].code.contains("not comment"));
    assert!(lines[1].comment.is_empty());
}

#[test]
fn cfg_test_marking_handles_semicolon_items() {
    let lines = lex("#[cfg(test)]\nmod tests;\nfn live() {}\n");
    assert!(lines[0].in_test && lines[1].in_test);
    assert!(!lines[2].in_test);
}
