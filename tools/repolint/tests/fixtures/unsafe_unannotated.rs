// Fixture: unsafe blocks/impls with and without annotations.

struct Wrapper(*mut f32);

// SAFETY: single-owner pointer; the annotated impl is compliant.
unsafe impl Send for Wrapper {}

unsafe impl Sync for Wrapper {} // first finding: unannotated impl

fn annotated_block(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p is valid and aligned.
    unsafe { *p }
}

fn unannotated_block(p: *const f32) -> f32 {
    unsafe { *p } // second finding: unannotated block
}

unsafe fn declares_obligation(p: *const f32) -> f32 {
    // The `unsafe fn` header is not flagged; the body block without an
    // annotation is the third finding.
    unsafe { *p }
}
