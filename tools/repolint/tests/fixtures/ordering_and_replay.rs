// Fixture: atomics with and without justifications, plus wall-clock
// reads in the replay-determinism scope.

use std::sync::atomic::{AtomicU64, Ordering};

fn annotated_above(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — pure counter, no data guarded.
    c.fetch_add(1, Ordering::Relaxed)
}

fn annotated_trailing(c: &AtomicU64) -> u64 {
    c.load(Ordering::SeqCst) // ordering: SeqCst, total order for determinism
}

fn missing_justification(c: &AtomicU64) -> u64 {
    c.load(Ordering::Relaxed) // finding 1
}

fn missing_justification_seqcst(c: &AtomicU64) {
    c.store(7, Ordering::SeqCst); // finding 2
}

fn acquire_release_exempt(c: &AtomicU64) -> u64 {
    c.store(1, Ordering::Release);
    c.load(Ordering::Acquire)
}

fn wall_clock() -> std::time::Duration {
    let t = std::time::Instant::now(); // finding 3 (replay scope)
    t.elapsed()
}

fn system_time_epoch() {
    let _ = std::time::SystemTime::now(); // finding 4 (replay scope)
}
