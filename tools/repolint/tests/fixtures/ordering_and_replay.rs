// Fixture: atomics with and without justifications, plus wall-clock
// reads in the replay-determinism scope.

use std::sync::atomic::{AtomicU64, Ordering}; // sync-shim finding in scope

fn annotated_above(c: &AtomicU64) -> u64 {
    // ordering: Relaxed — pure counter, no data guarded.
    c.fetch_add(1, Ordering::Relaxed)
}

fn annotated_trailing(c: &AtomicU64) -> u64 {
    c.load(Ordering::SeqCst) // ordering: SeqCst, total order for determinism
}

fn annotated_acquire(c: &AtomicU64) -> u64 {
    // ordering: Acquire — pairs with a Release store elsewhere.
    c.load(Ordering::Acquire)
}

fn missing_justification(c: &AtomicU64) -> u64 {
    c.load(Ordering::Relaxed) // finding 1
}

fn missing_justification_seqcst(c: &AtomicU64) {
    c.store(7, Ordering::SeqCst); // finding 2
}

fn missing_justification_release(c: &AtomicU64) {
    c.store(1, Ordering::Release); // finding 3
}

fn missing_justification_acqrel(c: &AtomicU64) -> u64 {
    c.fetch_add(1, Ordering::AcqRel) // finding 4
}

fn wall_clock() -> std::time::Duration {
    let t = std::time::Instant::now(); // replay finding 1
    t.elapsed()
}

fn system_time_epoch() {
    let _ = std::time::SystemTime::now(); // replay finding 2
}
