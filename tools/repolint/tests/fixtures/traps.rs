//! Fixture: strings and comments that look like violations but are not.
// A comment mentioning .unwrap() and panic!("boom") must not count.
/* block comment with unsafe { *p } and Ordering::SeqCst inside */

fn strings() -> Vec<String> {
    vec![
        "call .unwrap() and .expect(\"x\") here".to_string(),
        "panic!(\"not real\") and todo!()".to_string(),
        r#"raw: unsafe { Ordering::Relaxed } and Instant::now()"#.to_string(),
        r##"hashed raw: .unwrap() "# still inside "## .to_string(),
        "escaped quote \" then panic!(\"still a string\")".to_string(),
        b"byte string with .unwrap() inside"
            .iter()
            .map(|&b| b as char)
            .collect(),
    ]
}

fn chars_and_lifetimes<'a>(x: &'a str) -> (&'a str, char, char) {
    let quote = '"';
    let brace = '{';
    (x, quote, brace)
}

/* nested /* block */ comments: .expect("ignored") */

fn multi_line_string() -> String {
    "line one .unwrap()
     line two panic!(\"x\")"
        .to_string()
}
