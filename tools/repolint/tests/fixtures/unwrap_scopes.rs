// Fixture: test-gated vs non-test panic-family calls.

fn hot_path(v: &[u32]) -> u32 {
    let first = v.first().unwrap(); // finding 1
    let second = v.get(1).expect("second"); // finding 2
    if v.len() > 9000 {
        panic!("too big"); // finding 3
    }
    first + second
}

fn tolerated(v: &[u32]) -> u32 {
    // unwrap_or / unwrap_or_else cannot panic and must not count.
    v.first().copied().unwrap_or_else(|| 0) + v.get(1).copied().unwrap_or(0)
}

#[cfg(not(test))]
fn also_production(v: &[u32]) -> u32 {
    v.first().copied().unwrap() // finding 4: cfg(not(test)) is live code
}

#[cfg(any(test, unix))]
fn maybe_production() {
    todo!() // finding 5: may still compile outside test builds
}

#[cfg(test)]
mod tests {
    #[test]
    fn gated_calls_do_not_count() {
        super::hot_path(&[1, 2]).to_string().parse::<u32>().unwrap();
        assert!(std::panic::catch_unwind(|| panic!("in test")).is_err());
        Vec::<u32>::new().first().expect("still in tests");
    }
}

#[cfg(all(test, feature = "slow"))]
fn gated_helper() {
    Vec::<u32>::new().first().unwrap();
}
