// Fixture: raw std::sync imports and uses on a model-checked path,
// plus traps that must not count.

use std::sync::atomic::AtomicUsize; // finding 1
use std::sync::Mutex; // finding 2

use crate::util::sync::atomic::AtomicU64; // clean: the shim path

fn qualified_use() -> bool {
    let b = std::sync::atomic::AtomicBool::new(false); // finding 3
    b.into_inner()
}

fn traps() -> String {
    // a comment mentioning std::sync must not count
    "a string mentioning std::sync must not count".to_string()
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex; // exempt: test-gated code may use std directly

    #[test]
    fn uses_std() {
        let _ = Mutex::new(0u32);
    }
}
