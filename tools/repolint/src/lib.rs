//! Repo-invariant linter for the largevis sources.
//!
//! A dependency-free static-analysis pass that lexes Rust source files
//! (comment- and string-aware, with `#[cfg(test)]`-scope tracking) and
//! enforces the invariants the test suite cannot express:
//!
//! - **no-panic** — no `unwrap()` / `expect()` / `panic!` / `todo!` in
//!   non-test code on the serving and durability paths (`serve/`,
//!   `data/formats/`, `coordinator/`, `util/faultio.rs`,
//!   `knn/search.rs`). These paths must propagate errors: a panic in a
//!   handler thread or mid-WAL-write is an availability or durability
//!   bug, not a programming convenience.
//! - **unsafe-safety** — every `unsafe` block and `unsafe impl` must be
//!   preceded by (or carry) a `// SAFETY:` comment stating why the
//!   obligation holds.
//! - **replay-determinism** — no `Instant::now` / `SystemTime` /
//!   `thread_rng` in the deterministic replay path (`wal.rs`,
//!   `vis/incremental.rs`): WAL replay must be a pure function of the
//!   log bytes.
//! - **ordering-justified** — every explicit `Ordering::` use
//!   (`Relaxed`, `SeqCst`, `Acquire`, `Release`, `AcqRel`) must carry
//!   an `// ordering:` comment justifying the choice (what
//!   happens-before edge it provides, or why none is needed). The sync
//!   shim itself (`util/sync/`) is exempt: it *interprets* orderings
//!   passed by callers (matching on them, forwarding them), so per-site
//!   justifications would be noise — the model-checker semantics are
//!   documented once at the module level instead.
//! - **sync-shim** — non-test code on the model-checked paths
//!   (`serve/`, `data/chunked.rs`, `data/formats/wal.rs`,
//!   `util/pool.rs`, `util/notify.rs`) must import concurrency
//!   primitives via `util::sync`, never `std::sync` directly: a raw
//!   `std::sync` type on those paths is invisible to the deterministic
//!   scheduler, silently shrinking what `tools/modelcheck` explores.
//!
//! The lexer is not a full Rust parser: it splits each line into a
//! *code* part (string/char-literal contents blanked) and a *comment*
//! part, and marks lines belonging to items gated behind a
//! definitely-false `cfg` predicate (three-valued evaluation with
//! `test` = false and unknown atoms left indeterminate, so
//! `cfg(not(test))` and `cfg(any(test, unix))` still count as non-test
//! code). That is exactly enough to make the four rules above immune to
//! false positives from strings, comments, and test modules.

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};

/// Rule id: panic-family calls on no-panic paths.
pub const RULE_NO_PANIC: &str = "no-panic";
/// Rule id: `unsafe` block/impl without a `// SAFETY:` comment.
pub const RULE_UNSAFE_SAFETY: &str = "unsafe-safety";
/// Rule id: wall-clock/random sources in the replay path.
pub const RULE_REPLAY_DETERMINISM: &str = "replay-determinism";
/// Rule id: unannotated explicit `Ordering::` use.
pub const RULE_ORDERING_JUSTIFIED: &str = "ordering-justified";
/// Rule id: raw `std::sync` on a model-checked path.
pub const RULE_SYNC_SHIM: &str = "sync-shim";

/// All rule ids, in report order.
pub const RULES: [&str; 5] = [
    RULE_NO_PANIC,
    RULE_UNSAFE_SAFETY,
    RULE_REPLAY_DETERMINISM,
    RULE_ORDERING_JUSTIFIED,
    RULE_SYNC_SHIM,
];

/// One source line after lexing.
#[derive(Debug, Default, Clone)]
pub struct LexedLine {
    /// Code on this line, with string/char-literal contents blanked and
    /// comments stripped (quotes are kept as markers).
    pub code: String,
    /// Concatenated comment text appearing on this line.
    pub comment: String,
    /// True when the line belongs to an item gated behind a cfg
    /// predicate that is definitely false outside `cfg(test)` builds.
    pub in_test: bool,
}

/// A single rule violation.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Which rule fired (one of [`RULES`]).
    pub rule: &'static str,
    /// `/`-separated path relative to the scan root.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line, trimmed.
    pub text: String,
    /// True when an allow-list entry covers this violation.
    pub allowed: bool,
}

/// One allow-list entry: `rule path-substring [line-substring]`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    /// Rule id the entry applies to.
    pub rule: String,
    /// Substring that must occur in the violation's relative path.
    pub path_sub: String,
    /// Optional substring that must occur in the offending line.
    pub line_sub: Option<String>,
}

impl AllowEntry {
    fn matches(&self, v: &Violation) -> bool {
        let line_ok = match &self.line_sub {
            Some(s) => v.text.contains(s.as_str()),
            None => true,
        };
        self.rule == v.rule && v.path.contains(&self.path_sub) && line_ok
    }
}

/// Scan configuration: which paths each scoped rule applies to, plus
/// the allow-list. Paths are matched as substrings of the
/// `/`-separated path relative to the scan root.
#[derive(Debug, Clone)]
pub struct Options {
    /// Scope of the no-panic rule.
    pub panic_scope: Vec<String>,
    /// Scope of the replay-determinism rule.
    pub determinism_scope: Vec<String>,
    /// Scope of the sync-shim rule (paths that must import via
    /// `util::sync`).
    pub sync_scope: Vec<String>,
    /// Paths exempt from the ordering-justified rule (the shim layer
    /// that interprets orderings rather than choosing them).
    pub ordering_exempt: Vec<String>,
    /// Allow-list entries (see [`AllowEntry`]).
    pub allow: Vec<AllowEntry>,
}

impl Options {
    /// The scopes codified for this repository (relative to
    /// `rust/src`).
    pub fn repo_defaults() -> Options {
        Options {
            panic_scope: vec![
                "serve/".to_string(),
                "data/formats/".to_string(),
                "coordinator/".to_string(),
                "util/faultio.rs".to_string(),
                "knn/search.rs".to_string(),
            ],
            determinism_scope: vec![
                "data/formats/wal.rs".to_string(),
                "vis/incremental.rs".to_string(),
            ],
            sync_scope: vec![
                "serve/".to_string(),
                "data/chunked.rs".to_string(),
                "data/formats/wal.rs".to_string(),
                "util/pool.rs".to_string(),
                "util/notify.rs".to_string(),
            ],
            ordering_exempt: vec!["util/sync/".to_string()],
            allow: Vec::new(),
        }
    }
}

/// Parse an allow-list file: one entry per line,
/// `rule path-substring [line-substring...]`; `#` starts a comment.
pub fn parse_allow(text: &str) -> Vec<AllowEntry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(path_sub)) = (parts.next(), parts.next()) else {
            continue;
        };
        let rest: Vec<&str> = parts.collect();
        let line_sub = if rest.is_empty() { None } else { Some(rest.join(" ")) };
        out.push(AllowEntry {
            rule: rule.to_string(),
            path_sub: path_sub.to_string(),
            line_sub,
        });
    }
    out
}

// --------------------------------------------------------------- lexer

fn flush(lines: &mut Vec<LexedLine>, code: &mut String, comment: &mut String) {
    lines.push(LexedLine {
        code: std::mem::take(code),
        comment: std::mem::take(comment),
        in_test: false,
    });
}

/// Lex `source` into per-line code/comment splits with
/// `#[cfg(test)]`-scope marking. Never fails: malformed input degrades
/// to treating the remainder as code.
pub fn lex(source: &str) -> Vec<LexedLine> {
    let chars: Vec<char> = source.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        match c {
            '\n' => {
                flush(&mut lines, &mut code, &mut comment);
                i += 1;
            }
            '/' if chars.get(i + 1) == Some(&'/') => {
                i += 2;
                while i < n && chars[i] != '\n' {
                    comment.push(chars[i]);
                    i += 1;
                }
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                i += 2;
                let mut depth = 1u32;
                while i < n && depth > 0 {
                    if chars[i] == '\n' {
                        flush(&mut lines, &mut code, &mut comment);
                        i += 1;
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        comment.push_str("/*");
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
            }
            '"' => {
                code.push('"');
                i += 1;
                i = consume_str_body(&chars, i, &mut lines, &mut code, &mut comment);
            }
            'r' | 'b' if !prev_is_ident(&code) && raw_str_hashes(&chars, i).is_some() => {
                // raw (byte) string: r"..", r#".."#, br#".."# ...
                let (hashes, quote) = raw_str_hashes(&chars, i).unwrap_or((0, i));
                code.push('"');
                i = quote + 1;
                i = consume_raw_body(&chars, i, hashes, &mut lines, &mut code, &mut comment);
            }
            'b' if !prev_is_ident(&code) && chars.get(i + 1) == Some(&'"') => {
                // byte string b"..": escapes work like a normal string
                code.push('"');
                i += 2;
                i = consume_str_body(&chars, i, &mut lines, &mut code, &mut comment);
            }
            '\'' => {
                let is_char = chars.get(i + 1) == Some(&'\\')
                    || (chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\''));
                if is_char {
                    code.push('\'');
                    code.push('\'');
                    i += 1;
                    while i < n {
                        match chars[i] {
                            '\\' => i += 2,
                            '\'' => {
                                i += 1;
                                break;
                            }
                            '\n' => {
                                // malformed; keep line structure intact
                                flush(&mut lines, &mut code, &mut comment);
                                i += 1;
                            }
                            _ => i += 1,
                        }
                    }
                } else {
                    // lifetime marker
                    code.push('\'');
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        flush(&mut lines, &mut code, &mut comment);
    }
    mark_test_lines(&mut lines);
    lines
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// At `chars[i] == 'r' | 'b'`: if this starts a raw (byte) string,
/// return (hash count, index of the opening quote).
fn raw_str_hashes(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i + 1;
    if chars[i] == 'b' {
        if chars.get(j) != Some(&'r') {
            return None;
        }
        j += 1;
    }
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j))
    } else {
        None
    }
}

fn consume_str_body(
    chars: &[char],
    mut i: usize,
    lines: &mut Vec<LexedLine>,
    code: &mut String,
    comment: &mut String,
) -> usize {
    let n = chars.len();
    while i < n {
        match chars[i] {
            '\\' => i += 2,
            '"' => {
                code.push('"');
                return i + 1;
            }
            '\n' => {
                flush(lines, code, comment);
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn consume_raw_body(
    chars: &[char],
    mut i: usize,
    hashes: usize,
    lines: &mut Vec<LexedLine>,
    code: &mut String,
    comment: &mut String,
) -> usize {
    let n = chars.len();
    while i < n {
        if chars[i] == '"' {
            let closed = (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'));
            if closed {
                code.push('"');
                return i + 1 + hashes;
            }
            i += 1;
        } else if chars[i] == '\n' {
            flush(lines, code, comment);
            i += 1;
        } else {
            i += 1;
        }
    }
    i
}

// ------------------------------------------------- cfg(test) tracking

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Tri {
    True,
    False,
    Unknown,
}

fn tri_not(t: Tri) -> Tri {
    match t {
        Tri::True => Tri::False,
        Tri::False => Tri::True,
        Tri::Unknown => Tri::Unknown,
    }
}

/// Evaluate a cfg predicate under `test = false`, every other atom
/// unknown. `False` means the item definitely does not exist outside
/// test builds.
fn eval_cfg_pred(pred: &str) -> Tri {
    let pred = pred.trim();
    if let Some(open) = pred.find('(') {
        if !pred.ends_with(')') {
            return Tri::Unknown;
        }
        let name = pred[..open].trim();
        let inner = &pred[open + 1..pred.len() - 1];
        match name {
            "not" => tri_not(eval_cfg_pred(inner)),
            "all" => {
                let mut acc = Tri::True;
                for part in split_top_commas(inner) {
                    match eval_cfg_pred(&part) {
                        Tri::False => return Tri::False,
                        Tri::Unknown => acc = Tri::Unknown,
                        Tri::True => {}
                    }
                }
                acc
            }
            "any" => {
                let mut acc = Tri::False;
                for part in split_top_commas(inner) {
                    match eval_cfg_pred(&part) {
                        Tri::True => return Tri::True,
                        Tri::Unknown => acc = Tri::Unknown,
                        Tri::False => {}
                    }
                }
                acc
            }
            _ => Tri::Unknown,
        }
    } else if pred == "test" {
        Tri::False
    } else {
        Tri::Unknown
    }
}

/// Split on commas at paren depth 0. Input comes from lexed code, so
/// string contents are already blanked and cannot hide commas.
fn split_top_commas(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' => {
                depth += 1;
                cur.push(c);
            }
            ')' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => out.push(std::mem::take(&mut cur)),
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

/// Mark lines belonging to items behind a definitely-false cfg.
fn mark_test_lines(lines: &mut [LexedLine]) {
    let mut chars: Vec<(usize, char)> = Vec::new();
    for (li, l) in lines.iter().enumerate() {
        for c in l.code.chars() {
            chars.push((li, c));
        }
        chars.push((li, '\n'));
    }
    let n = chars.len();
    let mut i = 0usize;
    while i < n {
        if chars[i].1 != '#' {
            i += 1;
            continue;
        }
        let open = skip_ws(&chars, i + 1);
        if open >= n || chars[open].1 != '[' {
            i += 1;
            continue;
        }
        let (content, close) = balanced(&chars, open, '[', ']');
        let trimmed = content.trim_start();
        let is_off = trimmed
            .strip_prefix("cfg")
            .map(|rest| rest.trim_start())
            .and_then(|rest| rest.strip_prefix('('))
            .and_then(|rest| rest.strip_suffix(')'))
            .is_some_and(|pred| eval_cfg_pred(pred) == Tri::False);
        if !is_off {
            i = close + 1;
            continue;
        }
        let attr_line = chars[i].0;
        // Skip whitespace and any further attributes to the item start.
        let mut j = close + 1;
        loop {
            j = skip_ws(&chars, j);
            if j < n && chars[j].1 == '#' {
                let o2 = skip_ws(&chars, j + 1);
                if o2 < n && chars[o2].1 == '[' {
                    let (_, c2) = balanced(&chars, o2, '[', ']');
                    j = c2 + 1;
                    continue;
                }
            }
            break;
        }
        // Scan the item header for its body `{...}` or terminating `;`.
        let mut depth = 0i32;
        let mut k = j;
        let mut end_line = if j < n { chars[j].0 } else { attr_line };
        while k < n {
            match chars[k].1 {
                '(' | '[' => depth += 1,
                ')' | ']' => depth -= 1,
                '{' => {
                    let mut bd = 1i32;
                    let mut m = k + 1;
                    while m < n && bd > 0 {
                        match chars[m].1 {
                            '{' => bd += 1,
                            '}' => bd -= 1,
                            _ => {}
                        }
                        m += 1;
                    }
                    end_line = chars[m.saturating_sub(1)].0;
                    k = m;
                    break;
                }
                ';' if depth <= 0 => {
                    end_line = chars[k].0;
                    k += 1;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        for line in lines.iter_mut().take(end_line + 1).skip(attr_line) {
            line.in_test = true;
        }
        i = k.max(close + 1);
    }
}

fn skip_ws(chars: &[(usize, char)], mut i: usize) -> usize {
    while i < chars.len() && chars[i].1.is_whitespace() {
        i += 1;
    }
    i
}

/// Collect the contents between `chars[open]` (== `open_c`) and its
/// matching `close_c`; returns (content, index of the closer).
fn balanced(chars: &[(usize, char)], open: usize, open_c: char, close_c: char) -> (String, usize) {
    let mut depth = 0i32;
    let mut content = String::new();
    let mut i = open;
    while i < chars.len() {
        let c = chars[i].1;
        if c == open_c {
            depth += 1;
            if depth > 1 {
                content.push(c);
            }
        } else if c == close_c {
            depth -= 1;
            if depth == 0 {
                return (content, i);
            }
            content.push(c);
        } else if depth > 0 {
            content.push(c);
        }
        i += 1;
    }
    (content, chars.len().saturating_sub(1))
}

// ---------------------------------------------------------------- rules

fn method_call(code: &str, name: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(name) {
        let after = start + pos + name.len();
        if code[after..].starts_with('(') {
            return true;
        }
        start = after;
    }
    false
}

fn bang_macro(code: &str, name: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(name) {
        let p = start + pos;
        let ok_before = p == 0 || {
            let c = bytes[p - 1] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        if ok_before {
            return true;
        }
        start = p + name.len();
    }
    false
}

/// Does this line open an `unsafe` block or `unsafe impl`? (`unsafe
/// fn`/`unsafe trait`/`unsafe extern` declare obligations rather than
/// discharge them, so they are not flagged — their bodies hold the
/// `unsafe {}` blocks that are.)
fn opens_unsafe_block_or_impl(lexed: &[LexedLine], idx: usize) -> bool {
    let code = &lexed[idx].code;
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("unsafe") {
        let p = start + pos;
        start = p + "unsafe".len();
        let ok_before = p == 0 || {
            let c = bytes[p - 1] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        let ok_after = match code[start..].chars().next() {
            Some(c) => !(c.is_ascii_alphanumeric() || c == '_'),
            None => true,
        };
        if !ok_before || !ok_after {
            continue;
        }
        // What follows the keyword: rest of this line, else the first
        // non-empty code on following lines (rustfmt can wrap here).
        let mut rest = code[start..].trim_start().to_string();
        let mut j = idx + 1;
        while rest.is_empty() && j < lexed.len() {
            rest = lexed[j].code.trim().to_string();
            j += 1;
        }
        if rest.starts_with('{') || rest.starts_with("impl") {
            return true;
        }
    }
    false
}

/// True when the line (or the contiguous comment block directly above
/// it) carries `tag`. Single-line attributes (`#[cfg(...)]`,
/// `#[allow(...)]`, ...) between the comment and the code do not break
/// contiguity — an annotation above a cfg-gated statement still covers
/// it.
fn annotated(lexed: &[LexedLine], idx: usize, tag: &str) -> bool {
    if lexed[idx].comment.contains(tag) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let code = lexed[j].code.trim();
        if !code.is_empty() && !(code.starts_with("#[") && code.ends_with(']')) {
            return false;
        }
        if lexed[j].comment.contains(tag) {
            return true;
        }
    }
    false
}

/// Run every rule over one file's source. `rel_path` is the
/// `/`-separated path relative to the scan root (it selects which
/// scoped rules apply).
pub fn scan_source(rel_path: &str, source: &str, opts: &Options) -> Vec<Violation> {
    let lexed = lex(source);
    let raw: Vec<&str> = source.lines().collect();
    let in_scope = |scope: &[String]| scope.iter().any(|s| rel_path.contains(s.as_str()));
    let panic_scoped = in_scope(&opts.panic_scope);
    let determinism_scoped = in_scope(&opts.determinism_scope);
    let sync_scoped = in_scope(&opts.sync_scope);
    let ordering_exempt = in_scope(&opts.ordering_exempt);
    let mut out: Vec<Violation> = Vec::new();
    let mut push = |rule: &'static str, idx: usize, out: &mut Vec<Violation>| {
        out.push(Violation {
            rule,
            path: rel_path.to_string(),
            line: idx + 1,
            text: raw.get(idx).map(|s| s.trim().to_string()).unwrap_or_default(),
            allowed: false,
        });
    };
    for (idx, line) in lexed.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        if panic_scoped {
            for name in [".unwrap", ".expect"] {
                if method_call(code, name) {
                    push(RULE_NO_PANIC, idx, &mut out);
                }
            }
            for name in ["panic!", "todo!"] {
                if bang_macro(code, name) {
                    push(RULE_NO_PANIC, idx, &mut out);
                }
            }
        }
        if determinism_scoped {
            for pat in ["Instant::now", "SystemTime", "thread_rng"] {
                if code.contains(pat) {
                    push(RULE_REPLAY_DETERMINISM, idx, &mut out);
                }
            }
        }
        if !ordering_exempt
            && [
                "Ordering::Relaxed",
                "Ordering::SeqCst",
                "Ordering::Acquire",
                "Ordering::Release",
                "Ordering::AcqRel",
            ]
            .iter()
            .any(|p| code.contains(p))
            && !annotated(&lexed, idx, "ordering:")
        {
            push(RULE_ORDERING_JUSTIFIED, idx, &mut out);
        }
        if sync_scoped && code.contains("std::sync") {
            push(RULE_SYNC_SHIM, idx, &mut out);
        }
        if opens_unsafe_block_or_impl(&lexed, idx) && !annotated(&lexed, idx, "SAFETY:") {
            push(RULE_UNSAFE_SAFETY, idx, &mut out);
        }
    }
    for v in &mut out {
        v.allowed = opts.allow.iter().any(|a| a.matches(v));
    }
    out
}

// --------------------------------------------------------------- report

/// Aggregate scan result over a file tree.
#[derive(Debug, Default)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Every violation found, allowed or not.
    pub violations: Vec<Violation>,
}

impl Report {
    /// Violations not covered by the allow-list.
    pub fn denied(&self) -> usize {
        self.violations.iter().filter(|v| !v.allowed).count()
    }

    /// Violations covered by the allow-list.
    pub fn allowed(&self) -> usize {
        self.violations.iter().filter(|v| v.allowed).count()
    }

    /// Per-rule (denied, allowed) counts; every rule id is present.
    pub fn per_rule(&self) -> BTreeMap<&'static str, (usize, usize)> {
        let mut map: BTreeMap<&'static str, (usize, usize)> =
            RULES.iter().map(|&r| (r, (0, 0))).collect();
        for v in &self.violations {
            let e = map.entry(v.rule).or_insert((0, 0));
            if v.allowed {
                e.1 += 1;
            } else {
                e.0 += 1;
            }
        }
        map
    }

    /// Render the machine-readable JSON report.
    pub fn to_json(&self) -> String {
        let mut rules = String::new();
        for (i, (rule, (denied, allowed))) in self.per_rule().into_iter().enumerate() {
            if i > 0 {
                rules.push(',');
            }
            rules.push_str(&format!(
                "\"{}\":{{\"violations\":{},\"allowed\":{}}}",
                rule, denied, allowed
            ));
        }
        let mut items = String::new();
        for (i, v) in self.violations.iter().enumerate() {
            if i > 0 {
                items.push(',');
            }
            items.push_str(&format!(
                "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"allowed\":{},\"text\":\"{}\"}}",
                v.rule,
                json_escape(&v.path),
                v.line,
                v.allowed,
                json_escape(&v.text)
            ));
        }
        format!(
            concat!(
                "{{\"files_scanned\":{},\"violations\":{},\"allowed\":{},",
                "\"rules\":{{{}}},\"items\":[{}]}}\n"
            ),
            self.files_scanned,
            self.denied(),
            self.allowed(),
            rules,
            items
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Scan every `.rs` file under `root` (recursively, sorted order).
pub fn scan_path(root: &Path, opts: &Options) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut report = Report::default();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let source = std::fs::read_to_string(&path)?;
        report.violations.extend(scan_source(&rel, &source, opts));
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}
