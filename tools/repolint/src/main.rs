//! `cargo run -p repolint` — scan `rust/src` for invariant violations.
//!
//! Exit codes: 0 clean (or fully allow-listed), 1 violations, 2 usage
//! or I/O error.
//!
//! Flags:
//! - `--root <dir>`: repository root (default: inferred from this
//!   crate's manifest location, i.e. two levels up from
//!   `tools/repolint`).
//! - `--allow <file>`: allow-list file (default: `<root>/repolint.allow`
//!   when it exists). Format: `rule path-substring [line-substring]`
//!   per line, `#` comments.
//! - `--report <file>`: also write the JSON report here.
//! - `--quiet`: suppress the per-violation listing.

use repolint::{parse_allow, scan_path, Options};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn default_root() -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.ancestors().nth(2).unwrap_or(manifest).to_path_buf()
}

fn main() -> ExitCode {
    let mut root = default_root();
    let mut allow_path: Option<PathBuf> = None;
    let mut report_path: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage("--root needs a value"),
            },
            "--allow" => match args.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage("--allow needs a value"),
            },
            "--report" => match args.next() {
                Some(v) => report_path = Some(PathBuf::from(v)),
                None => return usage("--report needs a value"),
            },
            "--quiet" => quiet = true,
            other => return usage(&format!("unknown argument: {other}")),
        }
    }

    let mut opts = Options::repo_defaults();
    let allow_file = allow_path.unwrap_or_else(|| root.join("repolint.allow"));
    if allow_file.exists() {
        match std::fs::read_to_string(&allow_file) {
            Ok(text) => opts.allow = parse_allow(&text),
            Err(e) => {
                eprintln!("repolint: cannot read {}: {e}", allow_file.display());
                return ExitCode::from(2);
            }
        }
    }

    let src = root.join("rust").join("src");
    let report = match scan_path(&src, &opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("repolint: scan of {} failed: {e}", src.display());
            return ExitCode::from(2);
        }
    };

    if !quiet {
        for v in &report.violations {
            let marker = if v.allowed { " (allowed)" } else { "" };
            eprintln!("[{}] {}:{}{}: {}", v.rule, v.path, v.line, marker, v.text);
        }
    }
    for (rule, (denied, allowed)) in report.per_rule() {
        eprintln!("repolint: {rule}: {denied} violations, {allowed} allowed");
    }
    eprintln!(
        "repolint: {} files scanned, {} violations ({} allowed)",
        report.files_scanned,
        report.denied(),
        report.allowed()
    );

    if let Some(path) = report_path {
        if let Err(e) = std::fs::write(&path, report.to_json()) {
            eprintln!("repolint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    if report.denied() > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("repolint: {msg}");
    eprintln!("usage: repolint [--root DIR] [--allow FILE] [--report FILE] [--quiet]");
    ExitCode::from(2)
}
