//! Model-check harness for the `largevis` sync shim.
//!
//! The library half of `tools/modelcheck` has two layers:
//!
//! * [`report`] — always compiled: a dependency-free JSON row for the
//!   CI artifact (`LARGEVIS_MODELCHECK_REPORT` names the directory the
//!   integration tests drop one file per model into).
//! * [`models`] — only under `--cfg modelcheck`: the closed concurrency
//!   models for the epoch-swap, COW-snapshot, WAL, doorbell and
//!   worker-latch protocols, each driven through
//!   `largevis::util::sync::model` (bounded-exhaustive DFS by default,
//!   seeded PCT via `LARGEVIS_MODELCHECK_MODE=pct`).
//!
//! The integration tests split along the mutation axis:
//!
//! * `tests/models.rs` — the invariants, compiled only when **no**
//!   `modelcheck_mutant_*` cfg is set; every model must pass its whole
//!   schedule budget.
//! * `tests/mutants.rs` — compiled per mutant cfg; each test asserts
//!   the checker *finds* the seeded bug (`failure.is_some()`), which is
//!   what gates the checker's own sensitivity in CI.
//!
//! Without `--cfg modelcheck` this crate still builds and its unit
//! tests run, so plain `cargo test -p modelcheck` stays green in the
//! ordinary workspace build.

pub mod report {
    //! Flat JSON rows for the CI report artifact (no serde offline —
    //! the shape is small enough to render by hand).

    use std::io::Write;
    use std::path::Path;

    /// One explored model's outcome, flattened for the JSON artifact.
    #[derive(Clone, Debug)]
    pub struct Row {
        /// Model name (also the artifact file stem).
        pub name: String,
        /// `"dfs"` or `"pct"`.
        pub mode: String,
        /// Seed used (PCT; echoed for DFS).
        pub seed: u64,
        /// Schedules executed.
        pub schedules: u64,
        /// Whether the exploration finished its tree/budget.
        pub complete: bool,
        /// Longest schedule, in decision steps.
        pub max_steps: u64,
        /// Preemption bound in force (DFS).
        pub preemption_bound: u32,
        /// Most preemptions any executed schedule spent.
        pub max_preemptions: u32,
        /// Failure message, when a schedule violated an invariant.
        pub failure: Option<String>,
        /// True when this row comes from a mutation-corpus run, where a
        /// failure is the *expected* outcome.
        pub expect_failure: bool,
    }

    /// Escape `s` for inclusion in a JSON string literal.
    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    impl Row {
        /// Render as a single JSON object.
        pub fn to_json(&self) -> String {
            let failure = match &self.failure {
                Some(m) => format!("\"{}\"", escape(m)),
                None => "null".to_string(),
            };
            format!(
                "{{\"name\":\"{}\",\"mode\":\"{}\",\"seed\":{},\"schedules\":{},\
                 \"complete\":{},\"max_steps\":{},\"preemption_bound\":{},\
                 \"max_preemptions\":{},\"expect_failure\":{},\"failure\":{}}}",
                escape(&self.name),
                escape(&self.mode),
                self.seed,
                self.schedules,
                self.complete,
                self.max_steps,
                self.preemption_bound,
                self.max_preemptions,
                self.expect_failure,
                failure,
            )
        }

        /// Write `<dir>/<name>.json` (one file per model so parallel
        /// test threads never contend on a shared artifact).
        pub fn write_to_dir(&self, dir: &Path) -> std::io::Result<()> {
            std::fs::create_dir_all(dir)?;
            let path = dir.join(format!("{}.json", self.name));
            let mut f = std::fs::File::create(path)?;
            f.write_all(self.to_json().as_bytes())?;
            f.write_all(b"\n")
        }

        /// [`Row::write_to_dir`] into `$LARGEVIS_MODELCHECK_REPORT`, a
        /// silent no-op when the variable is unset (local runs).
        pub fn write_to_env_dir(&self) -> std::io::Result<()> {
            match std::env::var_os("LARGEVIS_MODELCHECK_REPORT") {
                Some(dir) => self.write_to_dir(Path::new(&dir)),
                None => Ok(()),
            }
        }
    }
}

#[cfg(modelcheck)]
pub mod models {
    //! The closed protocol models. Each `*_model` function is one
    //! deterministic scenario suitable for [`explore`]: it rebuilds all
    //! of its state per schedule and asserts its invariant inline, so a
    //! violating interleaving surfaces as a captured panic (or a
    //! detected deadlock) in the schedule report.

    use crate::report::Row;
    use largevis::data::chunked::{copied_bytes, ChunkedMatrix};
    use largevis::data::formats::wal::{read_wal_file, RecoveryPolicy, WalWriter};
    use largevis::data::matrix::Matrix;
    use largevis::serve::epoch::EpochCell;
    use largevis::util::faultio::{FaultKind, FaultPlan, FaultStorage};
    use largevis::util::notify::Doorbell;
    use largevis::util::pool::DoneLatch;
    use largevis::util::sync::atomic::{AtomicU64, Ordering};
    use largevis::util::sync::model::{explore, Config, Report};
    use largevis::util::sync::{thread, Arc, Mutex};
    use std::time::Duration;

    fn row_from(report: &Report, expect_failure: bool) -> Row {
        Row {
            name: report.name.clone(),
            mode: format!("{:?}", report.mode).to_ascii_lowercase(),
            seed: report.seed,
            schedules: report.schedules,
            complete: report.complete,
            max_steps: report.max_steps,
            preemption_bound: report.preemption_bound,
            max_preemptions: report.max_preemptions,
            failure: report.failure.as_ref().map(|f| f.message.clone()),
            expect_failure,
        }
    }

    /// Explore `f` under the environment-configured budget, emit a
    /// report row, and panic (with the failing trace) on any violation
    /// — the assertion form the invariant tests use.
    pub fn run(name: &str, f: impl Fn() + Send + Sync) {
        let report = explore(name, Config::from_env(), f);
        let _ = row_from(&report, false).write_to_env_dir();
        if let Some(fail) = &report.failure {
            panic!(
                "model '{name}' failed on schedule {} of {} ({:?}): {}\n  trace tail:\n  {}",
                fail.schedule,
                report.schedules,
                report.mode,
                fail.message,
                fail.trace.join("\n  "),
            );
        }
    }

    /// Mutation-corpus assertion: the checker must *find* a violation
    /// of `f` within the budget, proving it would catch this bug class.
    pub fn expect_detected(name: &str, f: impl Fn() + Send + Sync) {
        let report = explore(name, Config::from_env(), f);
        let detected = report.failure.is_some();
        let _ = row_from(&report, true).write_to_env_dir();
        assert!(
            detected,
            "seeded bug '{name}' survived {} schedules ({:?}, seed {}) undetected — \
             the checker lost sensitivity to this bug class",
            report.schedules, report.mode, report.seed,
        );
    }

    // ------------------------------------------------------ scenarios

    /// Invariant (a): a reader never observes a torn epoch — if the
    /// lock-free hint says `e`, the cell holds a payload of epoch
    /// `>= e`, and the payload is internally consistent. The
    /// `modelcheck_mutant_epoch_first` corpus entry (publish bumps the
    /// counter before the swap) violates exactly this.
    pub fn epoch_torn_read_model() {
        let cell = EpochCell::new(Arc::new((0u64, 0u64)));
        thread::scope(|s| {
            let cell = &cell;
            s.spawn(move || {
                for e in 1..=2u64 {
                    cell.publish(e, Arc::new((e, e)));
                }
            });
            let mut last_hint = 0;
            for _ in 0..2 {
                let h = cell.hint();
                assert!(h >= last_hint, "epoch hint went backwards: {last_hint} -> {h}");
                last_hint = h;
                let v = cell.get();
                assert!(v.0 == v.1, "payload mixes epochs: ({}, {})", v.0, v.1);
                assert!(
                    v.0 >= h,
                    "torn read: hint said epoch {h} but the cell held epoch {}",
                    v.0
                );
            }
        });
    }

    /// Invariant (b): a snapshot held across later publishes stays
    /// bitwise frozen — the writer's copy-on-write mutations must never
    /// leak into chunks shared with an older epoch — and the COW byte
    /// counter is monotone under concurrency.
    pub fn cow_frozen_epoch_model() {
        let base = ChunkedMatrix::from_matrix(&Matrix::from_vec(vec![1.0; 8], 4, 2), 2);
        let cell = EpochCell::new(Arc::new(base.clone()));
        thread::scope(|s| {
            let cell = &cell;
            s.spawn(move || {
                let mut local = base;
                for step in 0..2u64 {
                    local.row_mut(0)[0] = 10.0 + step as f32;
                    cell.publish(step + 1, Arc::new(local.clone()));
                }
            });
            let held = cell.get();
            let flatten =
                |m: &ChunkedMatrix| (0..m.n()).flat_map(|i| m.row(i).to_vec()).collect::<Vec<_>>();
            let before = flatten(&held);
            let c0 = copied_bytes();
            // Instrumented ops between the two reads give the writer
            // schedule points to publish (and COW-copy) in between.
            let _ = cell.hint();
            let c1 = copied_bytes();
            assert!(c1 >= c0, "copied_bytes went backwards: {c0} -> {c1}");
            let after = flatten(&held);
            assert!(
                before == after,
                "held epoch mutated under a later publish: {before:?} -> {after:?}"
            );
        });
    }

    /// Fresh WAL path per schedule — uninstrumented file state must not
    /// leak between schedules.
    fn fresh_wal_path() -> std::path::PathBuf {
        use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
        static NEXT: StdAtomicU64 = StdAtomicU64::new(0);
        let id = NEXT.fetch_add(1, StdOrdering::Relaxed);
        let dir = std::env::temp_dir().join(format!("largevis_modelcheck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create model tempdir");
        dir.join(format!("model_{id}.wal"))
    }

    /// Number of storage ops (writes + fsyncs) consumed by creating the
    /// model WAL plus `appends` successful appends — probed once so the
    /// fault trigger can be aimed at an exact append's write.
    fn wal_ops_for(appends: usize) -> u64 {
        let path = fresh_wal_path();
        let storage = FaultStorage::probe();
        let mut w = WalWriter::create(&storage, &path, 2, 0).expect("probe create");
        for i in 0..appends {
            let batch = Matrix::from_vec(vec![i as f32, -(i as f32)], 1, 2);
            w.append(&batch).expect("probe append");
        }
        let ops = storage.ops();
        drop(w);
        let _ = std::fs::remove_file(&path);
        ops
    }

    /// Invariant (c): recovery returns **exactly the acked prefix** —
    /// every append whose sequence number was returned `Ok` is
    /// replayed, and nothing else — under any interleaving of appends,
    /// a mid-stream short-write + rollback, and a concurrent reader.
    /// The `modelcheck_mutant_wal_no_rollback` corpus entry (failed
    /// append leaves its torn tail in place) breaks this: the next
    /// successful append lands after garbage, so replay truncates away
    /// an acked record.
    ///
    /// File I/O is uninstrumented (the scheduler cannot preempt inside
    /// a syscall), so writer and reader serialize on a shim [`Mutex`]
    /// at *batch* granularity — the interleavings explored are
    /// append-vs-read orderings, which is where the rollback invariant
    /// lives.
    pub fn wal_acked_prefix_model() {
        // Aim a transient short write at the *second* append's payload
        // write: ops [0, k1) cover create + append #1, so index k1 is
        // the next write.
        let trigger = wal_ops_for(1);
        let path = fresh_wal_path();
        let storage = FaultStorage::new(FaultPlan {
            kind: FaultKind::ShortWrite,
            trigger_op: trigger,
            seed: 7,
        });
        let mut writer = WalWriter::create(&storage, &path, 2, 0).expect("create model WAL");
        let acked: Mutex<Vec<Matrix>> = Mutex::new(Vec::new());
        let io = Mutex::new(());
        thread::scope(|s| {
            let (acked, io, path) = (&acked, &io, &path);
            let writer = &mut writer;
            s.spawn(move || {
                for i in 0..3u32 {
                    let batch = Matrix::from_vec(vec![i as f32, -(i as f32)], 1, 2);
                    let _serial = io.lock().unwrap();
                    if writer.append(&batch).is_ok() {
                        acked.lock().unwrap().push(batch);
                    }
                }
            });
            for _ in 0..2 {
                let _serial = io.lock().unwrap();
                let contents = read_wal_file(path, 2, RecoveryPolicy::Truncate)
                    .expect("concurrent WAL read");
                let acked = acked.lock().unwrap();
                assert!(
                    contents.batches.len() == acked.len(),
                    "recovery saw {} batches but {} were acked",
                    contents.batches.len(),
                    acked.len()
                );
                for (got, want) in contents.batches.iter().zip(acked.iter()) {
                    assert!(
                        got.as_slice() == want.as_slice(),
                        "recovered batch diverges from acked batch"
                    );
                }
            }
        });
        // Final recovery after all appends: exactly the acked prefix.
        let contents =
            read_wal_file(&path, 2, RecoveryPolicy::Truncate).expect("final WAL read");
        let acked = acked.into_inner().unwrap();
        assert!(
            contents.batches.len() == acked.len(),
            "final recovery saw {} batches but {} were acked",
            contents.batches.len(),
            acked.len()
        );
        for (got, want) in contents.batches.iter().zip(acked.iter()) {
            assert!(
                got.as_slice() == want.as_slice(),
                "final recovered batch diverges from acked batch"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    /// Invariant (d): the refine doorbell never loses a ring — whatever
    /// order ring and wait interleave in, the waiter wakes and reports
    /// the bell rung. Under the model, `wait_timeout` never times out,
    /// so a lost wakeup shows up as a detected deadlock — which is
    /// exactly how the `modelcheck_mutant_bell_no_flag` corpus entry
    /// (ring skips the sticky bit) dies.
    pub fn doorbell_ring_model() {
        let bell = Doorbell::new();
        thread::scope(|s| {
            let bell = &bell;
            s.spawn(move || bell.ring());
            let rung = bell.wait_or(Duration::from_millis(1), || false);
            assert!(rung, "doorbell wait returned without the bell rung");
        });
    }

    /// Worker-teardown publication: any thread observing
    /// [`DoneLatch::is_done`] reads the workers' plain writes without
    /// further synchronization. Both latch corpus entries
    /// (`modelcheck_mutant_latch_relaxed` drops the Release half of
    /// `arrive`, `modelcheck_mutant_latch_weak_poll` drops the Acquire
    /// half of `is_done`) let the poller see the count hit zero while
    /// the payload candidate set still contains the stale initial
    /// value.
    pub fn latch_publish_model() {
        let latch = DoneLatch::new(1);
        let payload = AtomicU64::new(0);
        thread::scope(|s| {
            let (latch, payload) = (&latch, &payload);
            s.spawn(move || {
                payload.store(42, Ordering::Relaxed);
                latch.arrive();
            });
            // Bounded poll: the scope join below synchronizes anyway,
            // so giving up after a few probes is fine and keeps the
            // schedule tree small.
            for _ in 0..4 {
                if latch.is_done() {
                    let got = payload.load(Ordering::Relaxed);
                    assert!(
                        got == 42,
                        "latch opened before the worker's writes were published (read {got})"
                    );
                    break;
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::report::{escape, Row};

    fn sample(failure: Option<&str>) -> Row {
        Row {
            name: "epoch_cell".to_string(),
            mode: "dfs".to_string(),
            seed: 1,
            schedules: 37,
            complete: true,
            max_steps: 120,
            preemption_bound: 2,
            max_preemptions: 2,
            failure: failure.map(|s| s.to_string()),
            expect_failure: false,
        }
    }

    #[test]
    fn json_row_without_failure() {
        assert_eq!(
            sample(None).to_json(),
            "{\"name\":\"epoch_cell\",\"mode\":\"dfs\",\"seed\":1,\"schedules\":37,\
             \"complete\":true,\"max_steps\":120,\"preemption_bound\":2,\
             \"max_preemptions\":2,\"expect_failure\":false,\"failure\":null}"
        );
    }

    #[test]
    fn json_row_with_failure_is_escaped() {
        let row = sample(Some("torn \"read\"\nat step 3"));
        let json = row.to_json();
        assert!(json.contains("\"failure\":\"torn \\\"read\\\"\\nat step 3\""));
    }

    #[test]
    fn escape_handles_controls_and_backslashes() {
        assert_eq!(escape("a\\b"), "a\\\\b");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn write_to_dir_creates_one_file_per_model() {
        let dir = std::env::temp_dir()
            .join(format!("modelcheck_report_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        sample(None).write_to_dir(&dir).expect("write report row");
        let body = std::fs::read_to_string(dir.join("epoch_cell.json")).expect("read row back");
        assert_eq!(body.trim_end(), sample(None).to_json());
        std::fs::remove_dir_all(&dir).expect("cleanup");
    }
}
