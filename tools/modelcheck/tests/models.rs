//! The invariant leg of the model-check suite: every protocol model
//! must survive its whole schedule budget (exhaustively under the
//! default DFS driver; for a seeded sweep set
//! `LARGEVIS_MODELCHECK_MODE=pct` and vary `LARGEVIS_MODELCHECK_SEED`).
//!
//! Compiled only under `--cfg modelcheck` with **no** mutant cfg — the
//! mutation corpus runs through `tests/mutants.rs` instead, where a
//! found violation is the expected outcome.

#![cfg(all(
    modelcheck,
    not(any(
        modelcheck_mutant_bell_no_flag,
        modelcheck_mutant_latch_relaxed,
        modelcheck_mutant_latch_weak_poll,
        modelcheck_mutant_epoch_first,
        modelcheck_mutant_wal_no_rollback,
    ))
))]

use modelcheck::models;

/// Invariant (a): no reader ever observes a snapshot mixing two epochs
/// — the epoch hint and the published cell stay coupled.
#[test]
fn epoch_cell_never_torn() {
    models::run("epoch_cell_never_torn", models::epoch_torn_read_model);
}

/// Invariant (b): an epoch held across later publishes stays bitwise
/// frozen, and the COW byte counter is monotone.
#[test]
fn cow_snapshot_frozen_across_publishes() {
    models::run("cow_snapshot_frozen_across_publishes", models::cow_frozen_epoch_model);
}

/// Invariant (c): WAL recovery equals exactly the acked prefix under
/// any append / rollback / concurrent-reader interleaving.
#[test]
fn wal_recovery_equals_acked_prefix() {
    models::run("wal_recovery_equals_acked_prefix", models::wal_acked_prefix_model);
}

/// Invariant (d): the refine doorbell never deadlocks and never loses
/// a wakeup, whichever side runs first.
#[test]
fn doorbell_never_loses_a_ring() {
    models::run("doorbell_never_loses_a_ring", models::doorbell_ring_model);
}

/// Satellite regression: `DoneLatch::arrive`'s Release half publishes
/// worker writes to any thread polling `DoneLatch::is_done`.
#[test]
fn pool_latch_publishes_worker_writes() {
    models::run("pool_latch_publishes_worker_writes", models::latch_publish_model);
}
