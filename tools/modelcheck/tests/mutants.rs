//! The mutation-corpus leg: each `modelcheck_mutant_*` cfg seeds one
//! deliberate concurrency bug in the library (see the `#[cfg]`-gated
//! sites in `rust/src`), and the matching test here asserts the
//! checker *detects* it within the configured budget. CI builds this
//! suite once per mutant cfg; a mutant surviving exploration fails the
//! build, gating the checker's own sensitivity.
//!
//! Under a mutant cfg the invariant suite (`tests/models.rs`) is
//! compiled out — the violated invariant is the point.

#![cfg(modelcheck)]

#[cfg(any(
    modelcheck_mutant_bell_no_flag,
    modelcheck_mutant_latch_relaxed,
    modelcheck_mutant_latch_weak_poll,
    modelcheck_mutant_epoch_first,
    modelcheck_mutant_wal_no_rollback,
))]
use modelcheck::models;

/// `EpochCell::publish` bumps the epoch counter before swapping the
/// cell: a reader between the two observes hint `e` but fetches the
/// previous epoch's value.
#[cfg(modelcheck_mutant_epoch_first)]
#[test]
fn detects_epoch_published_before_swap() {
    models::expect_detected("mutant_epoch_first", models::epoch_torn_read_model);
}

/// `Doorbell::ring` skips the sticky bit, so a ring delivered before
/// the waiter parks is lost — a deadlock under the model's
/// never-times-out `wait_timeout`.
#[cfg(modelcheck_mutant_bell_no_flag)]
#[test]
fn detects_doorbell_without_sticky_bit() {
    models::expect_detected("mutant_bell_no_flag", models::doorbell_ring_model);
}

/// `DoneLatch::arrive` demoted to Relaxed: the count reaches zero
/// without publishing the workers' writes.
#[cfg(modelcheck_mutant_latch_relaxed)]
#[test]
fn detects_latch_arrive_without_release() {
    models::expect_detected("mutant_latch_relaxed", models::latch_publish_model);
}

/// `DoneLatch::is_done` demoted to Relaxed: the poller observes zero
/// without acquiring the arrivers' writes.
#[cfg(modelcheck_mutant_latch_weak_poll)]
#[test]
fn detects_latch_poll_without_acquire() {
    models::expect_detected("mutant_latch_weak_poll", models::latch_publish_model);
}

/// `WalWriter::append` leaves its torn tail in place after a failed
/// write: the next successful append lands after garbage and replay
/// truncates away an acked record.
#[cfg(modelcheck_mutant_wal_no_rollback)]
#[test]
fn detects_wal_append_without_rollback() {
    models::expect_detected("mutant_wal_no_rollback", models::wal_acked_prefix_model);
}
