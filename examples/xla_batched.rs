//! Demonstrates the three-layer AOT path in isolation:
//!
//! 1. rust builds a toy weighted graph,
//! 2. the `grad_kernel` HLO artifact (JAX/Pallas, lowered at build
//!    time) computes batched gradients on the PJRT CPU client,
//! 3. rust applies them — and cross-checks one batch against the native
//!    Hogwild gradient math.
//!
//! Also exercises the fused `largevis_step` artifact (gather + kernel +
//! scatter in one HLO) on a table of the manifest's baked size.
//!
//! ```text
//! make artifacts && cargo run --release --example xla_batched
//! ```

use largevis::data::synth::sbm;
use largevis::graph::CsrGraph;
use largevis::runtime::{literal_f32, literal_f32_2d, literal_to_f32, Runtime};
use largevis::util::rng::Rng;
use largevis::vis::objective::ProbFn;
use largevis::vis::{init_layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()?;
    let mf = rt.manifest;
    println!(
        "pjrt platform={} artifacts: batch={} M={} dim={}",
        rt.platform(),
        mf.batch,
        mf.negatives,
        mf.dim
    );

    // --- Cross-check the grad_kernel artifact against native math ---
    let (b, m, s) = (mf.batch, mf.negatives, mf.dim);
    let mut rng = Rng::new(1);
    let mk = |len: usize, rng: &mut Rng| -> Vec<f32> {
        (0..len).map(|_| rng.gaussian()).collect()
    };
    let yi = mk(b * s, &mut rng);
    let yj = mk(b * s, &mut rng);
    let yneg = mk(b * m * s, &mut rng);
    let gamma = 7.0f32;

    let outs = rt.run(
        "grad_kernel",
        &[
            literal_f32_2d(&yi, b, s)?,
            literal_f32_2d(&yj, b, s)?,
            literal_f32_2d(&yneg, b, m * s)?,
            literal_f32(gamma),
        ],
    )?;
    let gi = literal_to_f32(&outs[0])?;
    let f = ProbFn::InvQuad { a: 1.0 };
    let mut max_err = 0f32;
    for e in 0..b {
        // Native gradient for edge e (same math as the Hogwild engine).
        let mut want = [0f32; 8];
        let d2: f32 = (0..s).map(|k| (yi[e * s + k] - yj[e * s + k]).powi(2)).sum();
        let c = f.coeff_pos(d2);
        for k in 0..s {
            want[k] += (c * (yi[e * s + k] - yj[e * s + k])).clamp(-5.0, 5.0);
        }
        for neg in 0..m {
            let off = (e * m + neg) * s;
            let d2: f32 = (0..s).map(|k| (yi[e * s + k] - yneg[off + k]).powi(2)).sum();
            let c = gamma * f.coeff_neg(d2);
            for k in 0..s {
                want[k] += (c * (yi[e * s + k] - yneg[off + k])).clamp(-5.0, 5.0);
            }
        }
        for k in 0..s {
            max_err = max_err.max((gi[e * s + k] - want[k]).abs());
        }
    }
    println!("grad_kernel vs native max |err| over {b} edges = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "XLA/native gradient mismatch");

    // --- Run a full batched layout on an SBM graph via the artifact ---
    let g = sbm(3000, 6, 12.0, 1.0, 2);
    let edges: Vec<(u32, u32, f64)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
    let graph = CsrGraph::from_undirected(g.n, &edges);
    let cfg = LargeVisConfig { samples_per_vertex: 800, ..Default::default() };
    let mut y = init_layout(g.n, 2, 3);
    let rep = largevis::vis::batched::optimize_batched(&graph, &mut y, &cfg, &rt)?;
    println!(
        "batched layout: {} samples in {:.2}s ({:.0}k samples/s)",
        rep.samples,
        rep.seconds,
        rep.throughput() / 1e3
    );
    let acc = largevis::eval::knn_classifier::knn_accuracy(
        &y,
        &g.communities,
        &largevis::eval::knn_classifier::KnnEvalConfig { k: 5, sample: 2000, ..Default::default() },
    );
    println!("community knn-accuracy of XLA layout = {acc:.4}");
    anyhow::ensure!(acc > 0.5, "XLA layout failed to separate communities");
    println!("xla_batched OK");
    Ok(())
}
