//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full LargeVis
//! system on a real small workload — the `mnist-like` dataset at
//! 20,000 × 784 — through every layer:
//!
//!   dataset → RP-forest KNN + neighbor exploring → perplexity weights
//!   → Hogwild layout → KNN-classifier eval → SVG,
//!
//! then the same layout again through the **XLA path** (AOT JAX/Pallas
//! gradient artifact via PJRT) to prove the three layers compose, and a
//! BH t-SNE run for the paper's headline comparison. Prints a summary
//! table and logs the layout-objective curve.
//!
//! ```text
//! make artifacts && cargo run --release --example end_to_end
//! ```

use largevis::bench::Table;
use largevis::data::datasets;
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::graph::weights::{weighted_graph, WeightConfig};
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::knn::sampled_recall;
use largevis::render::{render_scatter, ScatterStyle};
use largevis::util::timer::{fmt_duration, Timer};
use largevis::vis::{init_layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::var("E2E_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.3);
    std::fs::create_dir_all("target/run")?;

    // ---- Stage 1: dataset (mnist-like, 784-d manifold clusters) ----
    let t = Timer::start("dataset");
    let ds = datasets::generate("mnist-like", scale, 0xe2e).unwrap();
    let labels = ds.labels.as_ref().unwrap();
    println!("dataset: {} n={} d={} ({} classes)", ds.name, ds.points.n(), ds.points.d(), ds.n_classes);
    let t_data = t.report();

    // ---- Stage 2: KNN graph ----
    let k = 50;
    let t = Timer::start("knn");
    let knn = largevis_knn(&ds.points, k, &LargeVisKnnConfig::default());
    let t_knn = t.report();
    let recall = sampled_recall(&ds.points, &knn, 300, 7, 0);
    println!("knn: k={k} recall≈{recall:.4} ({})", fmt_duration(t_knn));

    // ---- Stage 3: weights ----
    let t = Timer::start("weights");
    let graph = weighted_graph(&knn, &WeightConfig::default());
    let t_weights = t.report();

    // ---- Stage 4a: Hogwild layout ----
    let cfg = LargeVisConfig { samples_per_vertex: 3000, ..Default::default() };
    let t = Timer::start("layout/hogwild");
    let mut y_hogwild = init_layout(graph.n(), 2, cfg.seed);
    let rep = largevis::vis::sgd::optimize(&graph, &mut y_hogwild, &cfg);
    let t_hogwild = t.report();
    println!(
        "hogwild: {} samples, {:.2}M samples/s",
        rep.samples,
        rep.throughput() / 1e6
    );

    // ---- Stage 4b: XLA batched layout (three-layer integration) ----
    let (y_xla, t_xla) = match largevis::runtime::Runtime::from_default_dir() {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            let xcfg = LargeVisConfig { samples_per_vertex: 600, ..cfg.clone() };
            let t = Timer::start("layout/xla");
            let mut y = init_layout(graph.n(), 2, cfg.seed);
            let xrep = largevis::vis::batched::optimize_batched(&graph, &mut y, &xcfg, &rt)?;
            let secs = t.report();
            println!("xla: {} samples, {:.2}M samples/s", xrep.samples, xrep.throughput() / 1e6);
            (Some(y), secs)
        }
        Err(e) => {
            println!("xla path skipped: {e}");
            (None, 0.0)
        }
    };

    // ---- Stage 4c: BH t-SNE baseline ----
    let tsne_iters = 400;
    let t = Timer::start("layout/bhtsne");
    let y_tsne = largevis::baselines::bh_tsne(
        &graph,
        &largevis::baselines::BhTsneConfig { iters: tsne_iters, ..Default::default() },
    );
    let t_tsne = t.report();

    // ---- Stage 5: evaluation ----
    let ecfg = KnnEvalConfig { k: 5, sample: 3000, ..Default::default() };
    let acc_hogwild = knn_accuracy(&y_hogwild, labels, &ecfg);
    let acc_tsne = knn_accuracy(&y_tsne, labels, &ecfg);
    let acc_xla = y_xla.as_ref().map(|y| knn_accuracy(y, labels, &ecfg));

    let mut table = Table::new(
        "end-to-end: mnist-like (paper headline: LargeVis ≥ t-SNE quality, much faster)",
        &["engine", "layout time", "samples/s", "knn-acc@5"],
    );
    table.row(&[
        "largevis/hogwild".into(),
        fmt_duration(t_hogwild),
        format!("{:.2}M", rep.throughput() / 1e6),
        format!("{acc_hogwild:.4}"),
    ]);
    if let Some(acc) = acc_xla {
        table.row(&[
            "largevis/xla".into(),
            fmt_duration(t_xla),
            "-".into(),
            format!("{acc:.4}"),
        ]);
    }
    table.row(&[
        format!("bh-tsne({tsne_iters} it)"),
        fmt_duration(t_tsne),
        "-".into(),
        format!("{acc_tsne:.4}"),
    ]);
    table.print();
    table.write_tsv("end_to_end")?;

    // ---- Stage 6: render ----
    render_scatter(
        std::path::Path::new("target/run/e2e_largevis.svg"),
        &y_hogwild,
        Some(labels),
        ds.n_classes,
        &ScatterStyle { title: "LargeVis (hogwild)".into(), ..Default::default() },
    )?;
    render_scatter(
        std::path::Path::new("target/run/e2e_tsne.svg"),
        &y_tsne,
        Some(labels),
        ds.n_classes,
        &ScatterStyle { title: "BH t-SNE".into(), ..Default::default() },
    )?;
    println!(
        "\nstage times: data={} knn={} weights={} | total={}",
        fmt_duration(t_data),
        fmt_duration(t_knn),
        fmt_duration(t_weights),
        fmt_duration(t_data + t_knn + t_weights + t_hogwild)
    );
    println!("SVGs in target/run/");
    Ok(())
}
