//! Gallery: regenerate the paper's qualitative figures (Figs 8–9) —
//! LargeVis vs BH t-SNE layouts of 20NG/WikiDoc/LiveJournal analogs,
//! plus LargeVis-only WikiWord/CSAuthor panels, as SVGs in
//! `target/figures/`.
//!
//! Scale with `GALLERY_SCALE` (default 0.05 keeps the run in minutes).

use largevis::baselines::{bh_tsne, BhTsneConfig};
use largevis::data::datasets;
use largevis::graph::weights::{weighted_graph, WeightConfig};
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::render::{render_scatter, ScatterStyle};
use largevis::util::timer::Timer;
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    let scale: f64 =
        std::env::var("GALLERY_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    std::fs::create_dir_all("target/figures")?;

    // (dataset, also-run-tsne) — mirrors the panels of Figs 8 and 9.
    let panels = [
        ("20ng-like", true),
        ("wikidoc-like", true),
        ("livejournal-like", true),
        ("wikiword-like", false),
        ("csauthor-like", false),
    ];

    for (name, with_tsne) in panels {
        let t = Timer::start(name);
        // 20NG is small in the paper; render it at full size.
        let eff_scale = if name == "20ng-like" { 1.0 } else { scale };
        let ds = datasets::generate(name, eff_scale, 0xf1a).unwrap();
        let k = 50.min(ds.points.n() - 1);
        let knn = largevis_knn(&ds.points, k, &LargeVisKnnConfig::default());
        let graph = weighted_graph(&knn, &WeightConfig::default());

        // Unlabeled sets are colored by K-means of the high-dimensional
        // representations, exactly as the paper does (200 clusters).
        let (colors, n_colors): (Vec<u32>, usize) = match &ds.labels {
            Some(l) => (l.clone(), ds.n_classes),
            None => {
                let k_colors = 200.min(ds.points.n() / 10).max(2);
                let km = largevis::eval::kmeans(
                    &ds.points,
                    &largevis::eval::KMeansConfig { k: k_colors, ..Default::default() },
                );
                (km.assignment, k_colors)
            }
        };

        let y = layout(&graph, &LargeVisConfig { samples_per_vertex: 2000, ..Default::default() });
        render_scatter(
            std::path::Path::new(&format!("target/figures/fig8_{name}_largevis.svg")),
            &y,
            Some(&colors),
            n_colors,
            &ScatterStyle { title: format!("{name} — LargeVis"), ..Default::default() },
        )?;

        if with_tsne {
            let yt = bh_tsne(&graph, &BhTsneConfig { iters: 500, ..Default::default() });
            render_scatter(
                std::path::Path::new(&format!("target/figures/fig8_{name}_tsne.svg")),
                &yt,
                Some(&colors),
                n_colors,
                &ScatterStyle { title: format!("{name} — BH t-SNE"), ..Default::default() },
            )?;
        }
        t.report();
        println!("{name}: n={} rendered", ds.points.n());
    }
    println!("gallery SVGs in target/figures/");
    Ok(())
}
