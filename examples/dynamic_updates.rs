//! Dynamic data extension (the paper's future-work item): embed a base
//! corpus, then stream new points in batches — each batch is spliced
//! into the KNN graph and placed by localized SGD while the existing
//! view stays frozen; a final global re-optimization unfreezes all.
//!
//! ```text
//! cargo run --release --example dynamic_updates
//! ```

use largevis::data::synth::gaussian_mixture;
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::graph::weights::{weighted_graph, WeightConfig};
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::render::{render_scatter, ScatterStyle};
use largevis::util::timer::Timer;
use largevis::vis::incremental::IncrementalLayout;
use largevis::vis::LargeVisConfig;

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("target/run")?;
    // Base: 4000 points, stream: 4 batches of 250 from the same source.
    let (all, labels) = gaussian_mixture(5000, 32, 8, 0.2, 77);
    let base_ids: Vec<usize> = (0..4000).collect();
    let base = all.gather_rows(&base_ids);

    let t = Timer::start("base embed");
    let knn = largevis_knn(&base, 20, &LargeVisKnnConfig::default());
    let wcfg = WeightConfig { perplexity: 15.0, ..Default::default() };
    let vcfg = LargeVisConfig { samples_per_vertex: 3000, ..Default::default() };
    let graph = weighted_graph(&knn, &wcfg);
    let mut layout = largevis::vis::init_layout(base.n(), 2, 3);
    largevis::vis::sgd::optimize(&graph, &mut layout, &vcfg);
    t.report();

    let mut inc = IncrementalLayout::new(base, knn, layout, wcfg, vcfg);
    for batch in 0..4 {
        let ids: Vec<usize> = (4000 + batch * 250..4000 + (batch + 1) * 250).collect();
        let points = all.gather_rows(&ids);
        let t = Timer::start("insert batch");
        inc.add_points(&points);
        let secs = t.report();
        let acc = knn_accuracy(
            &inc.layout.to_matrix(),
            &labels[..inc.n()],
            &KnnEvalConfig { k: 5, sample: 2000, ..Default::default() },
        );
        println!("after batch {batch}: n={} accuracy={acc:.4} (insert took {secs:.2}s)", inc.n());
    }

    render_scatter(
        std::path::Path::new("target/run/dynamic_updates.svg"),
        &inc.layout.to_matrix(),
        Some(&labels),
        8,
        &ScatterStyle { title: "incremental insertions (frozen base)".into(), ..Default::default() },
    )?;

    let t = Timer::start("global reoptimize");
    inc.reoptimize();
    t.report();
    let acc = knn_accuracy(
        &inc.layout.to_matrix(),
        &labels,
        &KnnEvalConfig { k: 5, sample: 2000, ..Default::default() },
    );
    println!("after global reoptimize: accuracy={acc:.4}");
    println!("wrote target/run/dynamic_updates.svg");
    Ok(())
}
