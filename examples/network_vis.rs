//! Network visualization scenario (paper Fig 10: the DBLP conference
//! map): generate a hierarchical-community graph, embed it to 100-d
//! with LINE (the paper's preprocessing), visualize with LargeVis, and
//! verify communities separate.
//!
//! ```text
//! cargo run --release --example network_vis
//! ```

use largevis::data::synth::sbm;
use largevis::embed::line::{train_line, LineConfig};
use largevis::eval::knn_classifier::{knn_accuracy, KnnEvalConfig};
use largevis::graph::weights::{weighted_graph, WeightConfig};
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::render::{render_scatter, ScatterStyle};
use largevis::util::timer::Timer;
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("target/run")?;
    // "Conferences": 24 communities of papers, like Fig 10's venues.
    let n = 12_000;
    let communities = 24;
    let t = Timer::start("sbm graph");
    let g = sbm(n, communities, 14.0, 1.0, 0xdb1);
    t.report();
    println!("graph: n={} undirected edges={} communities={}", g.n, g.edges.len(), communities);

    // LINE 100-d preprocessing (exactly what the paper does for DBLP).
    let t = Timer::start("line embed");
    let edges: Vec<(u32, u32, f32)> = g.edges.iter().map(|&(a, b)| (a, b, 1.0)).collect();
    let emb = train_line(
        g.n,
        &edges,
        &LineConfig { dim: 100, samples_per_vertex: 1500, ..Default::default() },
    )
    .embedding;
    t.report();

    // LargeVis pipeline on the embeddings.
    let t = Timer::start("largevis");
    let knn = largevis_knn(&emb, 30, &LargeVisKnnConfig::default());
    let graph = weighted_graph(&knn, &WeightConfig::default());
    let y = layout(&graph, &LargeVisConfig { samples_per_vertex: 3000, ..Default::default() });
    t.report();

    let acc = knn_accuracy(
        &y,
        &g.communities,
        &KnnEvalConfig { k: 5, sample: 3000, ..Default::default() },
    );
    println!("community KNN-accuracy on 2D layout: {acc:.4} (chance = {:.4})", 1.0 / communities as f64);
    anyhow::ensure!(acc > 3.0 / communities as f64, "layout failed to separate communities");

    let path = std::path::Path::new("target/run/network_vis.svg");
    render_scatter(
        path,
        &y,
        Some(&g.communities),
        communities,
        &ScatterStyle { title: "dblp-like conference map (LargeVis)".into(), ..Default::default() },
    )?;
    println!("wrote {}", path.display());
    Ok(())
}
