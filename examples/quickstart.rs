//! Quickstart: visualize a swiss roll in ~30 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Generates 5k points on a swiss-roll manifold, builds the LargeVis
//! KNN graph, lays it out, and writes `target/run/quickstart.svg` — the
//! roll unrolls into colored bands.

use largevis::data::synth::swiss_roll;
use largevis::graph::weights::{weighted_graph, WeightConfig};
use largevis::knn::explore::{largevis_knn, LargeVisKnnConfig};
use largevis::render::{render_scatter, ScatterStyle};
use largevis::vis::{layout, LargeVisConfig};

fn main() -> anyhow::Result<()> {
    // 1. Data: 5000 points on a 3-d swiss roll (8 colored bands).
    let (points, labels) = swiss_roll(5000, 3, 8, 42);

    // 2. Approximate KNN graph (RP-forest + neighbor exploring).
    let knn = largevis_knn(&points, 20, &LargeVisKnnConfig::default());

    // 3. Perplexity-calibrated edge weights (Eqs. 1-2).
    let graph = weighted_graph(&knn, &WeightConfig { perplexity: 15.0, ..Default::default() });

    // 4. Probabilistic layout by asynchronous SGD (Eq. 6).
    let y = layout(&graph, &LargeVisConfig { samples_per_vertex: 3000, ..Default::default() });

    // 5. Render.
    std::fs::create_dir_all("target/run")?;
    let path = std::path::Path::new("target/run/quickstart.svg");
    render_scatter(path, &y, Some(&labels), 8, &ScatterStyle::default())?;
    println!("wrote {}", path.display());
    Ok(())
}
